//! Coordinator-side session telemetry: per-sample lineage rows,
//! staleness/latency histograms, and the report hub that the
//! `export_telemetry` verb drains remote span logs into.
//!
//! [`SessionTelemetry`] hangs off the session state and is fed by the
//! verb handlers in [`super::Session`]:
//!
//! * `lease_prompts` → [`SessionTelemetry::on_leased`] — the sample's
//!   clock starts, stamped with the lease's trace id.
//! * `put_chunk` → [`SessionTelemetry::on_chunk`] — first chunk closes
//!   the time-to-first-sample window; the finishing chunk records the
//!   generating policy version and the rollout duration.
//! * `put_batch` / `put_experience_data` / `notify_cells` →
//!   [`SessionTelemetry::on_cell`] — reward and advantage arrival.
//! * `get_batch` / `get_batch_meta` on a `train*` task →
//!   [`SessionTelemetry::on_consumed`] — the row enters a train batch;
//!   staleness (trainer version minus generating version) and queue
//!   age are observed.
//!
//! Every hook is a no-op while [`crate::telemetry::enabled`] is false,
//! so the telemetry-off path costs one atomic load per verb.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::metrics::Registry;
use crate::telemetry::{
    self, LineageRow, TelemetryReport, TelemetrySnapshot,
};
use crate::transfer_queue::GlobalIndex;

/// Most lineage rows retained; the oldest (smallest index) are evicted
/// past this, bounding memory for arbitrarily long runs.
pub const LINEAGE_CAP: usize = 4096;

/// Most spans retained per remote process in the report hub.
const HUB_SPAN_CAP: usize = 8192;

/// Histogram: trainer version minus generating policy version at the
/// moment a sample joins a train batch (paper §4.1 staleness bound).
pub const HIST_STALENESS: &str = "staleness_versions";
/// Histogram: lease grant → first generated chunk, milliseconds.
pub const HIST_TTFS: &str = "time_to_first_chunk_ms";
/// Histogram: lease grant → finishing chunk, milliseconds.
pub const HIST_ROLLOUT: &str = "rollout_ms";
/// Histogram: last lineage event → train consumption, milliseconds.
pub const HIST_QUEUE_AGE: &str = "queue_age_ms";

/// Per-session telemetry aggregation point (coordinator side).
#[derive(Default)]
pub struct SessionTelemetry {
    registry: Registry,
    /// Lineage keyed by global row index.
    lineage: Mutex<BTreeMap<u64, LineageRow>>,
    /// Latest report pushed per remote process name.
    hub: Mutex<BTreeMap<String, TelemetryReport>>,
}

impl SessionTelemetry {
    pub fn new() -> Self {
        Self::default()
    }

    /// The coordinator-side registry (histograms + counters exported
    /// in the snapshot's `coordinator` report).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// The lineage row for `index`, if still retained.
    pub fn lineage_row(&self, index: GlobalIndex) -> Option<LineageRow> {
        self.lineage.lock().unwrap().get(&index.0).copied()
    }

    fn update_row(
        &self,
        index: GlobalIndex,
        f: impl FnOnce(&mut LineageRow),
    ) {
        let mut g = self.lineage.lock().unwrap();
        let row = g.entry(index.0).or_insert_with(|| LineageRow {
            index: index.0,
            ..LineageRow::default()
        });
        f(row);
        while g.len() > LINEAGE_CAP {
            g.pop_first();
        }
    }

    /// Prompt rows granted to a rollout worker under `trace`.
    pub fn on_leased(&self, indices: &[GlobalIndex], trace: u64) {
        if !telemetry::enabled() {
            return;
        }
        let now = telemetry::now_us();
        for &idx in indices {
            // A re-lease (previous holder crashed) restarts the clock:
            // the timings describe the attempt that actually delivered.
            self.update_row(idx, |r| {
                r.trace = trace;
                r.leased_us = now;
                r.first_chunk_us = 0;
                r.last_chunk_us = 0;
            });
        }
        self.registry.inc("lineage.leased", indices.len() as u64);
    }

    /// A `put_chunk` increment for one row; `finished` commits it.
    pub fn on_chunk(
        &self,
        index: GlobalIndex,
        finished: bool,
        gen_version: u64,
    ) {
        if !telemetry::enabled() {
            return;
        }
        let now = telemetry::now_us();
        let mut first_ms = None;
        let mut rollout_ms = None;
        self.update_row(index, |r| {
            if r.first_chunk_us == 0 {
                r.first_chunk_us = now;
                if r.leased_us != 0 {
                    first_ms =
                        Some(us_to_ms(now.saturating_sub(r.leased_us)));
                }
            }
            if finished {
                r.last_chunk_us = now;
                r.gen_version = gen_version;
                if r.leased_us != 0 {
                    rollout_ms =
                        Some(us_to_ms(now.saturating_sub(r.leased_us)));
                }
            }
        });
        if let Some(ms) = first_ms {
            self.registry.observe(HIST_TTFS, ms);
        }
        if let Some(ms) = rollout_ms {
            self.registry.observe(HIST_ROLLOUT, ms);
            self.registry.inc("lineage.generated", 1);
        }
    }

    /// An experience cell landed for `index`; only reward and
    /// advantage columns advance lineage.
    pub fn on_cell(
        &self,
        index: GlobalIndex,
        column: &crate::transfer_queue::Column,
    ) {
        use crate::transfer_queue::Column;
        if !telemetry::enabled() {
            return;
        }
        let now = telemetry::now_us();
        match column {
            Column::Rewards => self.update_row(index, |r| {
                if r.reward_us == 0 {
                    r.reward_us = now;
                }
            }),
            Column::Advantages => self.update_row(index, |r| {
                if r.advantage_us == 0 {
                    r.advantage_us = now;
                }
            }),
            _ => {}
        }
    }

    /// Rows popped by a consumer of `task`. Only train-shaped tasks
    /// (name starting with `train`) close lineage; `train_version` is
    /// the parameter-store version the batch will be trained under.
    pub fn on_consumed(
        &self,
        task: &str,
        indices: &[GlobalIndex],
        train_version: u64,
    ) {
        if !telemetry::enabled() || !task.starts_with("train") {
            return;
        }
        let now = telemetry::now_us();
        let mut staleness = Vec::new();
        let mut queue_ages = Vec::new();
        {
            let mut g = self.lineage.lock().unwrap();
            for idx in indices {
                let Some(r) = g.get_mut(&idx.0) else { continue };
                r.train_us = now;
                r.train_version = train_version;
                // Staleness is only meaningful for rows that actually
                // went through generation (gen_version recorded).
                if r.last_chunk_us != 0 {
                    staleness.push(r.staleness() as f64);
                }
                let ready_us = r
                    .advantage_us
                    .max(r.reward_us)
                    .max(r.last_chunk_us);
                if ready_us != 0 && now > ready_us {
                    queue_ages.push(us_to_ms(now - ready_us));
                }
            }
        }
        for s in staleness {
            self.registry.observe(HIST_STALENESS, s);
        }
        for ms in queue_ages {
            self.registry.observe(HIST_QUEUE_AGE, ms);
        }
        self.registry.inc("lineage.trained", indices.len() as u64);
    }

    /// Merge a remote process's pushed report into the hub: spans
    /// accumulate (bounded), registry aggregates replace (they are
    /// cumulative snapshots).
    pub fn merge_report(&self, report: TelemetryReport) {
        let mut g = self.hub.lock().unwrap();
        let slot = g.entry(report.proc.clone()).or_insert_with(|| {
            TelemetryReport { proc: report.proc.clone(), ..Default::default() }
        });
        slot.spans.extend(report.spans);
        if slot.spans.len() > HUB_SPAN_CAP {
            let excess = slot.spans.len() - HUB_SPAN_CAP;
            slot.spans.drain(..excess);
        }
        slot.counters = report.counters;
        slot.hists = report.hists;
    }

    /// Serve one `export_telemetry` call: absorb the caller's pushed
    /// report (if any), drain the coordinator's own span log, and
    /// return the merged snapshot.
    pub fn export(
        &self,
        pushed: Option<TelemetryReport>,
    ) -> TelemetrySnapshot {
        if let Some(r) = pushed {
            self.merge_report(r);
        }
        let coordinator = TelemetryReport {
            proc: "coordinator".to_string(),
            spans: telemetry::global().drain(),
            counters: self.registry.counter_snapshots(),
            hists: self.registry.hist_snapshots(),
        };
        let mut procs = vec![coordinator];
        procs.extend(self.hub.lock().unwrap().values().cloned());
        let lineage =
            self.lineage.lock().unwrap().values().copied().collect();
        TelemetrySnapshot { procs, lineage }
    }
}

fn us_to_ms(us: u64) -> f64 {
    us as f64 / 1000.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Span;

    fn idx(i: u64) -> GlobalIndex {
        GlobalIndex(i)
    }

    #[test]
    fn lineage_chain_completes_and_observes_histograms() {
        let _g = telemetry::test_enable_gate();
        telemetry::set_enabled(Some(true));
        let t = SessionTelemetry::new();
        t.on_leased(&[idx(0), idx(1)], 77);
        t.on_chunk(idx(0), false, 0);
        t.on_chunk(idx(0), true, 3);
        t.on_cell(idx(0), &crate::transfer_queue::Column::Rewards);
        t.on_cell(idx(0), &crate::transfer_queue::Column::Advantages);
        t.on_consumed("train", &[idx(0)], 5);

        let row = t.lineage_row(idx(0)).unwrap();
        assert!(row.complete(), "all six timestamps set: {row:?}");
        assert_eq!(row.trace, 77);
        assert_eq!(row.staleness(), 2);
        let stale = t.registry().hist(HIST_STALENESS).unwrap();
        assert_eq!(stale.count, 1);
        assert_eq!(stale.max, 2.0);
        assert!(t.registry().hist(HIST_TTFS).unwrap().count == 1);
        // Row 1 never generated: no staleness sample, not complete.
        t.on_consumed("train", &[idx(1)], 5);
        assert!(!t.lineage_row(idx(1)).unwrap().complete());
        assert_eq!(
            t.registry().hist(HIST_STALENESS).unwrap().count,
            1
        );
        // Non-train consumers never close lineage.
        t.on_leased(&[idx(2)], 9);
        t.on_consumed("reward", &[idx(2)], 5);
        assert_eq!(t.lineage_row(idx(2)).unwrap().train_us, 0);
        telemetry::set_enabled(None);
    }

    #[test]
    fn hooks_are_inert_when_disabled() {
        let _g = telemetry::test_enable_gate();
        telemetry::set_enabled(Some(false));
        let t = SessionTelemetry::new();
        t.on_leased(&[idx(0)], 42);
        t.on_chunk(idx(0), true, 1);
        t.on_consumed("train", &[idx(0)], 2);
        assert!(t.lineage_row(idx(0)).is_none());
        assert!(t.registry().hist(HIST_STALENESS).is_none());
        telemetry::set_enabled(None);
    }

    #[test]
    fn lineage_is_bounded_by_evicting_oldest() {
        let _g = telemetry::test_enable_gate();
        telemetry::set_enabled(Some(true));
        let t = SessionTelemetry::new();
        for i in 0..(LINEAGE_CAP as u64 + 10) {
            t.on_leased(&[idx(i)], 1);
        }
        assert!(t.lineage_row(idx(0)).is_none(), "oldest evicted");
        assert!(t.lineage_row(idx(LINEAGE_CAP as u64 + 9)).is_some());
        telemetry::set_enabled(None);
    }

    #[test]
    fn hub_merges_reports_and_bounds_spans() {
        // export() drains the process-global span log: serialize with
        // tests that assert on that log's contents.
        let _g = telemetry::test_enable_gate();
        let t = SessionTelemetry::new();
        let mk = |n: usize| TelemetryReport {
            proc: "w0".into(),
            spans: (0..n)
                .map(|i| Span {
                    name: format!("s{i}"),
                    track: "w0".into(),
                    trace: 0,
                    t0_us: i as u64,
                    dur_us: 1,
                })
                .collect(),
            counters: vec![("c".into(), n as u64)],
            hists: vec![],
        };
        t.merge_report(mk(3));
        t.merge_report(mk(2));
        let snap = t.export(None);
        let w0 = snap.procs.iter().find(|p| p.proc == "w0").unwrap();
        assert_eq!(w0.spans.len(), 5, "spans accumulate");
        assert_eq!(w0.counters, vec![("c".to_string(), 2)]);
        assert_eq!(snap.procs[0].proc, "coordinator");
    }
}
