//! `ServiceClient` — the typed, transport-agnostic client for the
//! service API. Mirrors the wire verbs 1:1 as methods. Works identically
//! over [`InProcTransport`] (same process, zero copy) and
//! [`TcpJsonlTransport`] (remote service).
//!
//! Two client-side routing layers sit on top of the raw verbs:
//!
//! * **Dedicated long-poll channel.** `lease_prompts` and
//!   `subscribe_weights` park server-side; on a one-in-flight transport
//!   running them on the shared connection would serialize every other
//!   verb behind the stream mutex for the length of the poll. Against a
//!   pipelined transport ([`Transport::pipelined`]) the long-poll rides
//!   the main connection as just another in-flight `seq` — the
//!   multiplexed server parks it without blocking the stream. Only
//!   non-pipelined transports lazily open a sibling
//!   ([`Transport::open_sibling`]) and route the long-poll verbs there.
//! * **Direct data-plane fetch.** A TCP client ([`ServiceClient::connect`])
//!   learns the unit placement view and, when remote storage units are
//!   attached, exchanges *payloads* with them directly over the binary
//!   frame codec: reads go `get_batch_meta` → per-unit binary fetch,
//!   writes go `alloc_rows` → per-unit binary put → `notify_cells`.
//!   The coordinator socket carries metadata only. Rows on unattached
//!   or unreachable units fall back through the coordinator
//!   (`fetch_rows` / `put_batch`), so a dead unit degrades to the
//!   relay path instead of failing the stream.

use std::collections::{BTreeMap, HashMap};
use std::net::ToSocketAddrs;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::rollout::{ChunkRow, LeaseId, LeaseReply, LeaseSpec, WorkerStat};
use crate::runtime::{HostTensor, ParamSet};
use crate::telemetry::{self, TelemetryReport, TelemetrySnapshot};
use crate::weights::WeightsMeta;
use crate::transfer_queue::{
    Batch, Column, GlobalIndex, RemoteUnit, UnitCallError, UnitHandle,
    Value,
};

use super::protocol::{
    CellNote, GetBatchMetaReply, GetBatchReply, GetBatchSpec, PutRow,
    ServiceRequest, ServiceResponse, ServiceStats, SpecDecl, TaskDecl,
};
use super::transport::{
    InProcTransport, TcpJsonlTransport, TcpPipelinedTransport, Transport,
};
use super::Session;

/// How long a unit observed dead stays quarantined: placement views
/// adopted from server replies cannot resurrect it within this window,
/// so a stale server view (the coordinator detaches lazily, on its own
/// call failures) does not make every batch re-dial a dead endpoint.
/// An explicit [`ServiceClient::refresh_topology`] clears quarantine.
const UNIT_QUARANTINE: Duration = Duration::from_secs(5);

/// Cached data-plane placement: unit endpoints plus lazily dialed
/// binary connections.
#[derive(Default)]
struct Topology {
    endpoints: Vec<Option<String>>,
    conns: HashMap<usize, Arc<RemoteUnit>>,
    /// Units observed dead, with their quarantine deadline.
    quarantine: HashMap<usize, Instant>,
}

struct DirectDataPlane {
    /// Whether this client is allowed to exchange payloads with units
    /// directly (TCP clients; in-proc clients already have zero-copy
    /// access through the session).
    enabled: bool,
    topo: Mutex<Option<Topology>>,
}

/// Typed client over any [`Transport`].
#[derive(Clone)]
pub struct ServiceClient {
    transport: Arc<dyn Transport>,
    /// Sibling channel for long-poll verbs, opened on first use.
    slow: Arc<Mutex<Option<Arc<dyn Transport>>>>,
    data_plane: Arc<DirectDataPlane>,
}

impl ServiceClient {
    fn with_direct(transport: Arc<dyn Transport>, direct: bool) -> Self {
        ServiceClient {
            transport,
            slow: Arc::new(Mutex::new(None)),
            data_plane: Arc::new(DirectDataPlane {
                enabled: direct,
                topo: Mutex::new(None),
            }),
        }
    }

    /// Client over an arbitrary transport (payloads relay through it).
    pub fn new(transport: Arc<dyn Transport>) -> Self {
        Self::with_direct(transport, false)
    }

    /// Client bound to an in-process session (the zero-copy fast path).
    pub fn in_proc(session: Arc<Session>) -> Self {
        Self::new(Arc::new(InProcTransport::new(session)))
    }

    /// Client connected to a remote `asyncflow serve` instance. Payload
    /// traffic goes directly to attached storage units when the
    /// topology has any ([`ServiceClient::connect_relay`] opts out).
    ///
    /// Negotiates the pipelined control channel (binary frames when the
    /// server offers them); against an old server it degrades to
    /// strict-order JSONL automatically. [`ServiceClient::connect_jsonl`]
    /// keeps the classic one-in-flight JSONL transport for debugging.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Self> {
        Ok(Self::with_direct(
            Arc::new(TcpPipelinedTransport::connect(addr, true)?),
            true,
        ))
    }

    /// Like [`ServiceClient::connect`] but over the classic strict-order
    /// JSONL transport: one request in flight, human-readable wire. The
    /// debug surface, and the baseline leg of the control-plane bench.
    pub fn connect_jsonl(addr: impl ToSocketAddrs) -> Result<Self> {
        Ok(Self::with_direct(
            Arc::new(TcpJsonlTransport::connect(addr)?),
            true,
        ))
    }

    /// Like [`ServiceClient::connect`] but payloads always relay
    /// through the coordinator socket — the pre-placement behavior
    /// (and the baseline leg of the data-plane bench).
    pub fn connect_relay(addr: impl ToSocketAddrs) -> Result<Self> {
        Ok(Self::with_direct(
            Arc::new(TcpJsonlTransport::connect(addr)?),
            false,
        ))
    }

    /// Whether this client's transport crosses a process boundary.
    /// Remote consumers should take batches under consumer leases
    /// (their process can die mid-batch); in-process consumers share
    /// the server's fate and keep the lease-free fast path — the
    /// policy [`crate::pipeline::run_service_stage`] applies.
    pub fn is_remote(&self) -> bool {
        self.transport.is_remote()
    }

    /// `(sent, received)` bytes over this client's coordinator socket
    /// (metadata + any relayed payloads; `None` for in-proc).
    pub fn wire_bytes(&self) -> Option<(u64, u64)> {
        let main = self.transport.wire_bytes()?;
        let slow = self
            .slow
            .lock()
            .unwrap()
            .as_ref()
            .and_then(|t| t.wire_bytes())
            .unwrap_or((0, 0));
        Some((main.0 + slow.0, main.1 + slow.1))
    }

    fn call(&self, req: ServiceRequest) -> Result<ServiceResponse> {
        match self.transport.call(req)? {
            ServiceResponse::Err(msg) => bail!("service error: {msg}"),
            resp => Ok(resp),
        }
    }

    /// Route a long-poll verb. On a pipelined transport it shares the
    /// main connection — the multiplexed server parks it as a waker
    /// registration, so it never blocks other in-flight verbs. On
    /// one-in-flight transports it goes over a lazily opened sibling
    /// connection (falling back to the main transport when the sibling
    /// cannot be opened).
    fn slow_call(&self, req: ServiceRequest) -> Result<ServiceResponse> {
        if self.transport.pipelined() {
            return match self.transport.call(req)? {
                ServiceResponse::Err(msg) => {
                    bail!("service error: {msg}")
                }
                resp => Ok(resp),
            };
        }
        let transport = {
            let mut slow = self.slow.lock().unwrap();
            match &*slow {
                Some(t) => t.clone(),
                None => match self.transport.open_sibling() {
                    Ok(t) => {
                        *slow = Some(t.clone());
                        t
                    }
                    Err(_) => self.transport.clone(),
                },
            }
        };
        match transport.call(req)? {
            ServiceResponse::Err(msg) => bail!("service error: {msg}"),
            resp => Ok(resp),
        }
    }

    fn call_ok(&self, req: ServiceRequest) -> Result<()> {
        match self.call(req)? {
            ServiceResponse::Ok => Ok(()),
            _ => bail!("service returned an unexpected response kind"),
        }
    }

    fn call_indices(
        &self,
        req: ServiceRequest,
    ) -> Result<Vec<GlobalIndex>> {
        match self.call(req)? {
            ServiceResponse::Indices(idx) => Ok(idx),
            _ => bail!("service returned an unexpected response kind"),
        }
    }

    // ---- data-plane topology ----------------------------------------------

    /// Re-learn the unit placement view from the coordinator (call
    /// after attaching units mid-session). Connections to unchanged
    /// endpoints are kept; quarantined units get a fresh chance.
    pub fn refresh_topology(&self) -> Result<()> {
        if !self.data_plane.enabled {
            return Ok(());
        }
        let endpoints: Vec<Option<String>> = self
            .stats()?
            .units
            .iter()
            .map(|u| u.endpoint.clone())
            .collect();
        if let Some(t) = self.data_plane.topo.lock().unwrap().as_mut() {
            t.quarantine.clear();
        }
        self.install_endpoints(&endpoints);
        Ok(())
    }

    fn install_endpoints(&self, fresh: &[Option<String>]) {
        if !self.data_plane.enabled {
            return;
        }
        let mut topo = self.data_plane.topo.lock().unwrap();
        let t = topo.get_or_insert_with(Topology::default);
        if t.endpoints.as_slice() != fresh {
            let old = std::mem::replace(&mut t.endpoints, fresh.to_vec());
            // Keep only connections whose endpoint is unchanged.
            t.conns.retain(|u, _| {
                old.get(*u).and_then(|e| e.as_ref())
                    == fresh.get(*u).and_then(|e| e.as_ref())
            });
        }
        // A server view cannot resurrect a unit this client just saw
        // die — keep it on the fallback path until quarantine expires.
        let now = Instant::now();
        t.quarantine.retain(|_, until| *until > now);
        let quarantined: Vec<usize> =
            t.quarantine.keys().copied().collect();
        for unit in quarantined {
            if let Some(slot) = t.endpoints.get_mut(unit) {
                *slot = None;
            }
            t.conns.remove(&unit);
        }
    }

    /// The cached placement view, fetching it on first use. `Some` only
    /// when direct mode is on AND at least one unit is attached —
    /// otherwise callers take the plain relay path.
    fn direct_endpoints(&self) -> Option<Vec<Option<String>>> {
        if !self.data_plane.enabled {
            return None;
        }
        {
            let topo = self.data_plane.topo.lock().unwrap();
            if let Some(t) = &*topo {
                return if t.endpoints.iter().any(Option::is_some) {
                    Some(t.endpoints.clone())
                } else {
                    None
                };
            }
        }
        // First use: learn the topology. Errors (e.g. an uninitialized
        // session) leave the cache empty so the next call retries.
        let endpoints: Vec<Option<String>> = match self.stats() {
            Ok(s) => s.units.iter().map(|u| u.endpoint.clone()).collect(),
            Err(_) => return None,
        };
        self.install_endpoints(&endpoints);
        if endpoints.iter().any(Option::is_some) {
            Some(endpoints)
        } else {
            None
        }
    }

    fn unit_conn(&self, unit: usize, endpoint: &str) -> Arc<RemoteUnit> {
        let mut topo = self.data_plane.topo.lock().unwrap();
        let t = topo.get_or_insert_with(Topology::default);
        t.conns
            .entry(unit)
            .or_insert_with(|| Arc::new(RemoteUnit::new(endpoint)))
            .clone()
    }

    /// Forget a unit after a transport failure: payloads for its shard
    /// relay through the coordinator until the quarantine expires or an
    /// explicit `refresh_topology` clears it.
    fn mark_unit_dead(&self, unit: usize) {
        let mut topo = self.data_plane.topo.lock().unwrap();
        if let Some(t) = topo.as_mut() {
            t.conns.remove(&unit);
            if let Some(slot) = t.endpoints.get_mut(unit) {
                *slot = None;
            }
            t.quarantine
                .insert(unit, Instant::now() + UNIT_QUARANTINE);
        }
    }

    // ---- verbs ------------------------------------------------------------

    /// `init_engines`: install the task graph + initial weights on an
    /// uninitialized session (e.g. a freshly started `asyncflow serve
    /// --uninit` instance).
    pub fn init_engines(
        &self,
        spec: SpecDecl,
        params: ParamSet,
    ) -> Result<()> {
        self.call_ok(ServiceRequest::InitEngines { spec, params })
    }

    /// Register one more task on a live session.
    pub fn register_task(&self, task: TaskDecl) -> Result<()> {
        self.call_ok(ServiceRequest::RegisterTask { task })
    }

    /// Register a remote storage unit as payload authority for
    /// placement slot `unit` (`asyncflow storage-unit` announcing
    /// itself).
    pub fn attach_unit(&self, unit: usize, endpoint: &str) -> Result<()> {
        self.call_ok(ServiceRequest::AttachUnit {
            unit,
            endpoint: endpoint.to_string(),
        })
    }

    /// Reserve `count` fresh global indices (the direct-write path
    /// allocates addresses before pushing payloads to units).
    pub fn alloc_rows(&self, count: usize) -> Result<Vec<GlobalIndex>> {
        self.call_indices(ServiceRequest::AllocRows { count })
    }

    /// Metadata-only write notification: the payloads named here must
    /// already be stored on their owning units (value-first).
    pub fn notify_cells(&self, cells: &[CellNote]) -> Result<()> {
        self.call_ok(ServiceRequest::NotifyCells {
            cells: cells.to_vec(),
        })
    }

    /// `put_prompts_data`: batch prompt ingest; returns assigned indices.
    pub fn put_prompts_data(
        &self,
        prompts: &[Vec<i32>],
    ) -> Result<Vec<GlobalIndex>> {
        self.call_indices(ServiceRequest::PutPrompts {
            prompts: prompts.to_vec(),
        })
    }

    /// `put_experience_data`: single-cell write.
    pub fn put_experience_data(
        &self,
        index: GlobalIndex,
        column: Column,
        value: Value,
    ) -> Result<()> {
        self.call_ok(ServiceRequest::PutExperience {
            index,
            column,
            value,
        })
    }

    /// Batch-first write: many rows (new or existing) per round-trip.
    /// Returns one index per row, in order.
    ///
    /// With remote units attached (direct mode), payloads go value-first
    /// to their owning units over the binary codec and only metadata
    /// touches the coordinator; rows on unattached/unreachable units
    /// relay as before. Unlike the relay path, the direct path is not
    /// atomic across units: on an error some sub-batches may already be
    /// applied and notified, so a retry of the same logical rows can
    /// duplicate samples — treat a direct put_batch error as fatal for
    /// the stream, or use [`ServiceClient::connect_relay`] where
    /// all-or-nothing ingest matters.
    pub fn put_batch(
        &self,
        rows: Vec<PutRow>,
    ) -> Result<Vec<GlobalIndex>> {
        if let Some(units) = self.direct_endpoints() {
            return self.put_batch_direct(rows, units);
        }
        self.call_indices(ServiceRequest::PutBatch { rows })
    }

    fn put_batch_direct(
        &self,
        rows: Vec<PutRow>,
        units: Vec<Option<String>>,
    ) -> Result<Vec<GlobalIndex>> {
        let n = units.len().max(1);
        let need = rows.iter().filter(|r| r.index.is_none()).count();
        let fresh = if need > 0 {
            self.alloc_rows(need)?
        } else {
            Vec::new()
        };
        let mut fresh = fresh.into_iter();
        let mut out = Vec::with_capacity(rows.len());
        let mut direct: BTreeMap<usize, Vec<(GlobalIndex, Column, Value)>> =
            BTreeMap::new();
        let mut relay: Vec<PutRow> = Vec::new();
        for row in rows {
            let idx = match row.index {
                Some(i) => i,
                None => fresh.next().expect("allocated above"),
            };
            out.push(idx);
            let unit = (idx.0 % n as u64) as usize;
            if units.get(unit).map_or(false, Option::is_some) {
                let cells = direct.entry(unit).or_default();
                for (col, val) in row.cells {
                    cells.push((idx, col, val));
                }
            } else {
                relay.push(PutRow::at(idx, row.cells));
            }
        }
        let mut notes: Vec<CellNote> = Vec::new();
        for (unit, cells) in direct {
            let endpoint =
                units[unit].clone().expect("attached unit has endpoint");
            let conn = self.unit_conn(unit, &endpoint);
            match conn.put_cells(&cells) {
                Ok(()) => {
                    notes.extend(cells.iter().map(|(idx, col, val)| {
                        CellNote {
                            index: *idx,
                            column: col.clone(),
                            token_len: val.token_len(),
                        }
                    }));
                }
                Err(UnitCallError::Rejected(m)) => {
                    bail!("storage unit {unit} rejected the write: {m}")
                }
                Err(UnitCallError::Transport(_)) => {
                    // Dead unit: relay its cells through the
                    // coordinator instead (which fails over on its own
                    // side too).
                    self.mark_unit_dead(unit);
                    let mut by_row: BTreeMap<u64, Vec<(Column, Value)>> =
                        BTreeMap::new();
                    for (idx, col, val) in cells {
                        by_row.entry(idx.0).or_default().push((col, val));
                    }
                    for (raw, cs) in by_row {
                        relay.push(PutRow::at(GlobalIndex(raw), cs));
                    }
                }
            }
        }
        // The metadata notification and the relay put are independent
        // (they name disjoint rows' cells) — pipeline them as one burst
        // instead of two sequential round-trips.
        let mut reqs = Vec::new();
        if !notes.is_empty() {
            reqs.push(ServiceRequest::NotifyCells { cells: notes });
        }
        if !relay.is_empty() {
            reqs.push(ServiceRequest::PutBatch { rows: relay });
        }
        if !reqs.is_empty() {
            for resp in self.transport.call_many(reqs)? {
                match resp {
                    ServiceResponse::Ok
                    | ServiceResponse::Indices(_) => {}
                    ServiceResponse::Err(msg) => {
                        bail!("service error: {msg}")
                    }
                    _ => bail!(
                        "service returned an unexpected response kind"
                    ),
                }
            }
        }
        Ok(out)
    }

    /// `get_experience_data`, batch-first, with deadline semantics:
    /// `NotReady` means retry, `Closed` means the stream is drained.
    ///
    /// In direct mode this is `get_batch_meta` + payload fetch straight
    /// from the owning units, with a via-coordinator fallback for rows
    /// on unattached or unreachable units.
    pub fn get_batch(&self, spec: &GetBatchSpec) -> Result<GetBatchReply> {
        if self.direct_endpoints().is_some() {
            return self.get_batch_direct(spec);
        }
        match self.call(ServiceRequest::GetBatch(spec.clone()))? {
            ServiceResponse::Batch(reply) => Ok(reply),
            _ => bail!("service returned an unexpected response kind"),
        }
    }

    /// `get_batch` minus payloads: consumed indices + placement view
    /// (+ the consumer lease when `spec.consumer` asked for one).
    pub fn get_batch_meta(
        &self,
        spec: &GetBatchSpec,
    ) -> Result<GetBatchMetaReply> {
        match self.call(ServiceRequest::GetBatchMeta(spec.clone()))? {
            ServiceResponse::BatchMeta { indices, units, lease } => {
                Ok(GetBatchMetaReply::Ready { indices, units, lease })
            }
            ServiceResponse::Batch(GetBatchReply::NotReady) => {
                Ok(GetBatchMetaReply::NotReady)
            }
            ServiceResponse::Batch(GetBatchReply::Closed) => {
                Ok(GetBatchMetaReply::Closed)
            }
            _ => bail!("service returned an unexpected response kind"),
        }
    }

    /// Payload fetch by explicit indices through the coordinator (the
    /// relay/fallback path; no consumption).
    pub fn fetch_rows(
        &self,
        indices: &[GlobalIndex],
        columns: &[Column],
    ) -> Result<Batch> {
        match self.call(ServiceRequest::FetchRows {
            indices: indices.to_vec(),
            columns: columns.to_vec(),
        })? {
            ServiceResponse::Batch(GetBatchReply::Ready(b)) => Ok(b),
            _ => bail!("service returned an unexpected response kind"),
        }
    }

    fn get_batch_direct(
        &self,
        spec: &GetBatchSpec,
    ) -> Result<GetBatchReply> {
        let (indices, units, lease) = match self.get_batch_meta(spec)? {
            GetBatchMetaReply::NotReady => {
                return Ok(GetBatchReply::NotReady)
            }
            GetBatchMetaReply::Closed => return Ok(GetBatchReply::Closed),
            GetBatchMetaReply::Ready { indices, units, lease } => {
                (indices, units, lease)
            }
        };
        // The reply carries the authoritative placement — adopt it.
        self.install_endpoints(&units);
        let n = units.len().max(1);
        let mut rows: Vec<Option<Vec<Value>>> =
            (0..indices.len()).map(|_| None).collect();
        let mut by_unit: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (pos, idx) in indices.iter().enumerate() {
            by_unit
                .entry((idx.0 % n as u64) as usize)
                .or_default()
                .push(pos);
        }
        let mut fallback: Vec<usize> = Vec::new();
        for (unit, positions) in by_unit {
            let Some(endpoint) =
                units.get(unit).and_then(|e| e.clone())
            else {
                fallback.extend(positions);
                continue;
            };
            let conn = self.unit_conn(unit, &endpoint);
            let idxs: Vec<GlobalIndex> =
                positions.iter().map(|&p| indices[p]).collect();
            match conn.fetch_rows(&idxs, &spec.columns) {
                Ok(fetched) => {
                    for (&pos, row) in positions.iter().zip(fetched) {
                        match row {
                            Some(vals) => rows[pos] = Some(vals),
                            // The unit lacks a column (e.g. a cell that
                            // relayed through the coordinator before
                            // the unit attached): relay the row.
                            None => fallback.push(pos),
                        }
                    }
                }
                Err(UnitCallError::Rejected(_)) => {
                    fallback.extend(positions)
                }
                Err(UnitCallError::Transport(_)) => {
                    // Dead unit: reads fall back through the
                    // coordinator, which serves its replica.
                    self.mark_unit_dead(unit);
                    fallback.extend(positions);
                }
            }
        }
        if !fallback.is_empty() {
            let idxs: Vec<GlobalIndex> =
                fallback.iter().map(|&p| indices[p]).collect();
            let relayed = self.fetch_rows(&idxs, &spec.columns)?;
            for (&pos, row) in fallback.iter().zip(relayed.rows) {
                rows[pos] = Some(row);
            }
        }
        let rows: Vec<Vec<Value>> = rows
            .into_iter()
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| {
                anyhow!(
                    "payload fetch incomplete: a row is missing from \
                     both its unit and the coordinator"
                )
            })?;
        // A failed payload fetch above simply propagates: the lease
        // (granted on the metadata pop) will expire and requeue the
        // rows — the crash-safety story covers mid-fetch deaths too.
        let batch = Batch {
            indices,
            rows,
            columns: spec.columns.clone(),
        };
        Ok(match lease {
            Some(lease) => GetBatchReply::Leased { batch, lease },
            None => GetBatchReply::Ready(batch),
        })
    }

    /// Convenience loop over [`ServiceClient::get_batch`]: blocks until a
    /// batch is ready (`Some`) or the queue closes (`None`). Each retry
    /// long-polls for `spec.timeout_ms` (uses 50ms when the spec says 0,
    /// so the loop never spins hot).
    pub fn get_batch_blocking(
        &self,
        spec: &GetBatchSpec,
    ) -> Result<Option<Batch>> {
        self.get_batch_blocking_until(spec, || false)
    }

    /// Like [`ServiceClient::get_batch_blocking`] but aborts (returning
    /// `Ok(None)`) as soon as `abort()` turns true — the shutdown-aware
    /// worker loop.
    ///
    /// This API has no ack step, so a lease granted by `spec.consumer`
    /// is retired immediately — the classic fire-and-forget semantics.
    /// Crash-safe consumers (ack only after outputs land) use
    /// [`ServiceClient::get_batch_leased_blocking_until`] instead.
    pub fn get_batch_blocking_until(
        &self,
        spec: &GetBatchSpec,
        abort: impl Fn() -> bool,
    ) -> Result<Option<Batch>> {
        Ok(
            match self.get_batch_leased_blocking_until(spec, abort)? {
                Some(leased) => Some(leased.into_batch()?),
                None => None,
            },
        )
    }

    /// Leased variant of [`ServiceClient::get_batch_blocking_until`]:
    /// the returned [`LeasedBatch`] carries the consumer lease (if
    /// `spec.consumer` requested one) and acks it on
    /// [`LeasedBatch::ack`] or drop — so the ONLY way rows stay
    /// permanently consumed is this process surviving long enough to
    /// say so. A kill -9 between here and the ack leaves the lease
    /// un-acked, and the server requeues the rows on TTL expiry or
    /// connection drop.
    pub fn get_batch_leased_blocking_until(
        &self,
        spec: &GetBatchSpec,
        abort: impl Fn() -> bool,
    ) -> Result<Option<LeasedBatch>> {
        let mut spec = spec.clone();
        if spec.timeout_ms == 0 {
            spec.timeout_ms = 50;
        }
        loop {
            if abort() {
                return Ok(None);
            }
            match self.get_batch(&spec)? {
                GetBatchReply::Ready(batch) => {
                    return Ok(Some(LeasedBatch {
                        batch,
                        lease: None,
                        client: None,
                    }))
                }
                GetBatchReply::Leased { batch, lease } => {
                    return Ok(Some(LeasedBatch {
                        batch,
                        lease: Some(lease),
                        client: Some(self.clone()),
                    }))
                }
                GetBatchReply::NotReady => continue,
                GetBatchReply::Closed => return Ok(None),
            }
        }
    }

    /// `ack_batch`: retire a consumer lease after the outputs derived
    /// from its rows have been written back. An error means the lease
    /// already expired — the rows were requeued to a peer and this
    /// consumer's work for them is discarded.
    pub fn ack_batch(&self, lease: LeaseId) -> Result<()> {
        self.call_ok(ServiceRequest::AckBatch { lease })
    }

    /// Long-poll for a weight snapshot newer than `min_version`.
    /// `Ok(None)` means nothing newer arrived before the timeout — the
    /// server elides the payload for "no change" answers, so polling is
    /// cheap even over TCP. Runs on the dedicated long-poll channel.
    pub fn subscribe_weights(
        &self,
        min_version: u64,
        timeout_ms: u64,
    ) -> Result<Option<ParamSet>> {
        match self.slow_call(ServiceRequest::SubscribeWeights {
            min_version,
            timeout_ms,
        })? {
            ServiceResponse::Weights(p) => Ok(Some(p)),
            ServiceResponse::WeightsNotNewer { .. } => Ok(None),
            _ => bail!("service returned an unexpected response kind"),
        }
    }

    /// `subscribe_weights_meta`: long-poll the delta manifest of
    /// weights newer than `min_version` — a few bytes per tensor
    /// instead of the payloads. `Ok(None)` means nothing newer arrived
    /// before the timeout. Runs on the dedicated long-poll channel.
    /// The usual caller is [`crate::weights::WeightMirror::sync`],
    /// which also handles the fetch + assemble half.
    pub fn subscribe_weights_meta(
        &self,
        subscriber: &str,
        min_version: u64,
        timeout_ms: u64,
    ) -> Result<Option<WeightsMeta>> {
        match self.slow_call(ServiceRequest::SubscribeWeightsMeta {
            subscriber: subscriber.to_string(),
            min_version,
            timeout_ms,
        })? {
            ServiceResponse::WeightsMeta(m) => Ok(Some(m)),
            ServiceResponse::WeightsNotNewer { .. } => Ok(None),
            _ => bail!("service returned an unexpected response kind"),
        }
    }

    /// `fetch_tensors`: pull tensor payloads by manifest index through
    /// the coordinator — the fallback leg of the weight plane for slots
    /// without a reachable storage unit. Entries come back as
    /// `(index, content_version, tensor)`; the caller must check each
    /// content version against its manifest (the server always serves
    /// its latest snapshot).
    pub fn fetch_tensors(
        &self,
        version: u64,
        indices: &[u32],
    ) -> Result<Vec<(u32, u64, Arc<HostTensor>)>> {
        match self.call(ServiceRequest::FetchTensors {
            version,
            indices: indices.to_vec(),
        })? {
            ServiceResponse::Tensors { entries, .. } => Ok(entries),
            _ => bail!("service returned an unexpected response kind"),
        }
    }

    /// `weight_sync_notify`: publish a new weight snapshot.
    pub fn weight_sync_notify(&self, params: ParamSet) -> Result<()> {
        self.call_ok(ServiceRequest::WeightSync { params })
    }

    /// `lease_prompts`: lease ready prompt rows for an elastic rollout
    /// worker (server-side long-poll up to `spec.timeout_ms`). A reply
    /// without a lease means "nothing available right now" — poll
    /// again, unless `closed` says the stream is drained and nothing is
    /// in flight anywhere. Runs on the dedicated long-poll channel so a
    /// parked poll never blocks heartbeats or chunk uploads.
    pub fn lease_prompts(&self, spec: &LeaseSpec) -> Result<LeaseReply> {
        match self.slow_call(ServiceRequest::LeasePrompts(spec.clone()))? {
            ServiceResponse::Lease(reply) => Ok(reply),
            _ => bail!("service returned an unexpected response kind"),
        }
    }

    /// `put_chunk`: stream partial generations for leased rows (implicit
    /// heartbeat). Rows flagged `finished` commit to the queue.
    pub fn put_chunk(
        &self,
        lease: LeaseId,
        version: u64,
        rows: Vec<ChunkRow>,
    ) -> Result<()> {
        self.call_ok(ServiceRequest::PutChunk { lease, version, rows })
    }

    /// `renew_lease`: explicit heartbeat. `ttl_ms = 0` keeps the TTL
    /// granted at lease time. An error means the lease expired — drop
    /// the in-flight batch and lease afresh.
    pub fn renew_lease(&self, lease: LeaseId, ttl_ms: u64) -> Result<()> {
        self.call_ok(ServiceRequest::RenewLease { lease, ttl_ms })
    }

    /// `fail_lease`: surrender a lease after an engine fault so its
    /// undone rows requeue immediately instead of waiting out the TTL
    /// (fleet fallback routing). Idempotent on already-dead leases.
    pub fn fail_lease(&self, lease: LeaseId, reason: &str) -> Result<()> {
        self.call_ok(ServiceRequest::FailLease {
            lease,
            reason: reason.to_string(),
        })
    }

    /// `worker_stats`: per-rollout-worker load/progress snapshot.
    pub fn worker_stats(&self) -> Result<Vec<WorkerStat>> {
        match self.call(ServiceRequest::WorkerStats)? {
            ServiceResponse::Workers(ws) => Ok(ws),
            _ => bail!("service returned an unexpected response kind"),
        }
    }

    /// `export_telemetry`: push this process's drained telemetry
    /// (`Some`) and/or fetch the coordinator's merged cross-process
    /// snapshot. Fails with "unknown op" against pre-telemetry servers
    /// — callers that must tolerate old peers should treat any error
    /// as "telemetry unavailable".
    pub fn export_telemetry(
        &self,
        report: Option<TelemetryReport>,
    ) -> Result<TelemetrySnapshot> {
        match self.call(ServiceRequest::ExportTelemetry { report })? {
            ServiceResponse::Telemetry(snap) => Ok(snap),
            _ => bail!("service returned an unexpected response kind"),
        }
    }

    /// Drain this thread's active span log and push it to the
    /// coordinator under `proc`. Best-effort: a no-op when telemetry
    /// is disabled or there is nothing to push, and errors (e.g. an
    /// old server without the verb) are swallowed — the spans were
    /// drained either way, and telemetry must never fail a workload.
    pub fn push_telemetry(&self, proc: &str) {
        if !telemetry::enabled() {
            return;
        }
        // In-process callers without their own thread log share the
        // coordinator's global log; draining it here would relabel the
        // coordinator's spans as this worker's. Those spans are
        // exported under "coordinator" anyway.
        if !self.is_remote() && !telemetry::thread_log_installed() {
            return;
        }
        let spans = telemetry::active_log().drain();
        if spans.is_empty() {
            return;
        }
        let report = TelemetryReport {
            proc: proc.to_string(),
            spans,
            counters: Vec::new(),
            hists: Vec::new(),
        };
        let _ = self.export_telemetry(Some(report));
    }

    /// Queue/param introspection.
    pub fn stats(&self) -> Result<ServiceStats> {
        match self.call(ServiceRequest::Stats)? {
            ServiceResponse::Stats(s) => Ok(s),
            _ => bail!("service returned an unexpected response kind"),
        }
    }

    /// Global-batch GC of fully consumed rows.
    pub fn evict(&self, indices: &[GlobalIndex]) -> Result<()> {
        self.call_ok(ServiceRequest::Evict { indices: indices.to_vec() })
    }

    /// Close the queue; consumers drain and observe `Closed`.
    pub fn shutdown(&self) -> Result<()> {
        self.call_ok(ServiceRequest::Shutdown)
    }

    /// Start a burst of small fire-and-forget verbs (heartbeats, acks,
    /// metadata notifications). On a pipelined transport the whole
    /// burst goes out as one write and the replies stream back tagged
    /// by `seq` — one round-trip instead of N. On one-in-flight
    /// transports it degrades to sequential calls with identical
    /// semantics.
    pub fn burst(&self) -> Burst<'_> {
        Burst { client: self, reqs: Vec::new() }
    }
}

/// Builder for a pipelined burst of fire-and-forget verbs — see
/// [`ServiceClient::burst`]. Every verb in the burst expects a bare
/// `ok` reply; [`Burst::send`] reports the first failure by position.
pub struct Burst<'a> {
    client: &'a ServiceClient,
    reqs: Vec<ServiceRequest>,
}

impl Burst<'_> {
    /// Queue a `renew_lease` heartbeat.
    pub fn renew_lease(mut self, lease: LeaseId, ttl_ms: u64) -> Self {
        self.reqs.push(ServiceRequest::RenewLease { lease, ttl_ms });
        self
    }

    /// Queue an `ack_batch` (consumer lease retirement).
    pub fn ack_batch(mut self, lease: LeaseId) -> Self {
        self.reqs.push(ServiceRequest::AckBatch { lease });
        self
    }

    /// Queue a `notify_cells` metadata write notification.
    pub fn notify_cells(mut self, cells: &[CellNote]) -> Self {
        self.reqs.push(ServiceRequest::NotifyCells {
            cells: cells.to_vec(),
        });
        self
    }

    /// Queue a `put_chunk` upload (implicit heartbeat).
    pub fn put_chunk(
        mut self,
        lease: LeaseId,
        version: u64,
        rows: Vec<ChunkRow>,
    ) -> Self {
        self.reqs.push(ServiceRequest::PutChunk { lease, version, rows });
        self
    }

    /// Number of queued verbs.
    pub fn len(&self) -> usize {
        self.reqs.len()
    }

    /// Whether the burst is empty (sending an empty burst is a no-op).
    pub fn is_empty(&self) -> bool {
        self.reqs.is_empty()
    }

    /// Send the burst and wait for every reply. All verbs are delivered
    /// in order even on failure replies; the first non-`ok` reply is
    /// reported (later verbs in the burst still executed server-side).
    pub fn send(self) -> Result<()> {
        if self.reqs.is_empty() {
            return Ok(());
        }
        let ops: Vec<&'static str> =
            self.reqs.iter().map(|r| r.op_name()).collect();
        let resps = self.client.transport.call_many(self.reqs)?;
        for (i, resp) in resps.iter().enumerate() {
            let op = ops.get(i).copied().unwrap_or("?");
            match resp {
                ServiceResponse::Ok => {}
                ServiceResponse::Err(msg) => {
                    bail!("service error on burst verb {i} ({op}): {msg}")
                }
                _ => bail!(
                    "unexpected response kind on burst verb {i} ({op})"
                ),
            }
        }
        Ok(())
    }
}

/// A batch plus the consumer lease it was served under (if any) — the
/// RAII face of crash-safe consumption.
///
/// The intended flow is *process → write outputs → [`LeasedBatch::ack`]*:
/// the lease is retired only after the outputs are durable, so a
/// process killed anywhere in between leaves an un-acked lease whose
/// rows the server requeues (TTL expiry, or immediately when the
/// connection drops). Dropping the handle without an explicit ack also
/// acks, best-effort — drops happen on in-process teardown paths where
/// the graph is already draining, and silently leaking a live lease
/// from a *healthy* process would requeue rows that were in fact
/// handled. A killed process never runs `Drop`; that is the point.
pub struct LeasedBatch {
    /// The served rows.
    pub batch: Batch,
    lease: Option<LeaseId>,
    client: Option<ServiceClient>,
}

impl LeasedBatch {
    /// The consumer lease id, when the batch was served under one.
    pub fn lease(&self) -> Option<LeaseId> {
        self.lease
    }

    /// Retire the lease (no-op for unleased batches). Call after the
    /// outputs derived from this batch have been written back; an error
    /// means the lease expired and the rows were requeued to a peer.
    pub fn ack(mut self) -> Result<()> {
        let lease = self.lease.take();
        let client = self.client.take();
        if let (Some(lease), Some(client)) = (lease, client) {
            client.ack_batch(lease)?;
        }
        Ok(())
    }

    /// Ack (propagating errors) and return the batch — for callers that
    /// want the old fire-and-forget semantics.
    pub fn into_batch(mut self) -> Result<Batch> {
        let batch = std::mem::replace(
            &mut self.batch,
            Batch { indices: vec![], rows: vec![], columns: vec![] },
        );
        let lease = self.lease.take();
        let client = self.client.take();
        drop(self);
        if let (Some(lease), Some(client)) = (lease, client) {
            client.ack_batch(lease)?;
        }
        Ok(batch)
    }
}

impl Drop for LeasedBatch {
    fn drop(&mut self) {
        if let (Some(lease), Some(client)) =
            (self.lease.take(), self.client.take())
        {
            let _ = client.ack_batch(lease);
        }
    }
}
