//! `ServiceClient` — the typed, transport-agnostic client for the
//! service API. Mirrors the wire verbs 1:1 as methods; every method is
//! exactly one [`Transport::call`] round-trip. Works identically over
//! [`InProcTransport`] (same process, zero copy) and
//! [`TcpJsonlTransport`] (remote service).

use std::sync::Arc;
use std::net::ToSocketAddrs;

use anyhow::{bail, Result};

use crate::rollout::{ChunkRow, LeaseId, LeaseReply, LeaseSpec, WorkerStat};
use crate::runtime::ParamSet;
use crate::transfer_queue::{Batch, Column, GlobalIndex, Value};

use super::protocol::{
    GetBatchReply, GetBatchSpec, PutRow, ServiceRequest, ServiceResponse,
    ServiceStats, SpecDecl, TaskDecl,
};
use super::transport::{InProcTransport, TcpJsonlTransport, Transport};
use super::Session;

/// Typed client over any [`Transport`].
#[derive(Clone)]
pub struct ServiceClient {
    transport: Arc<dyn Transport>,
}

impl ServiceClient {
    pub fn new(transport: Arc<dyn Transport>) -> Self {
        ServiceClient { transport }
    }

    /// Client bound to an in-process session (the zero-copy fast path).
    pub fn in_proc(session: Arc<Session>) -> Self {
        ServiceClient::new(Arc::new(InProcTransport::new(session)))
    }

    /// Client connected to a remote `asyncflow serve` instance.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Self> {
        Ok(ServiceClient::new(Arc::new(TcpJsonlTransport::connect(
            addr,
        )?)))
    }

    fn call(&self, req: ServiceRequest) -> Result<ServiceResponse> {
        match self.transport.call(req)? {
            ServiceResponse::Err(msg) => bail!("service error: {msg}"),
            resp => Ok(resp),
        }
    }

    fn call_ok(&self, req: ServiceRequest) -> Result<()> {
        match self.call(req)? {
            ServiceResponse::Ok => Ok(()),
            _ => bail!("service returned an unexpected response kind"),
        }
    }

    fn call_indices(
        &self,
        req: ServiceRequest,
    ) -> Result<Vec<GlobalIndex>> {
        match self.call(req)? {
            ServiceResponse::Indices(idx) => Ok(idx),
            _ => bail!("service returned an unexpected response kind"),
        }
    }

    // ---- verbs ------------------------------------------------------------

    /// `init_engines`: install the task graph + initial weights on an
    /// uninitialized session (e.g. a freshly started `asyncflow serve
    /// --uninit` instance).
    pub fn init_engines(
        &self,
        spec: SpecDecl,
        params: ParamSet,
    ) -> Result<()> {
        self.call_ok(ServiceRequest::InitEngines { spec, params })
    }

    /// Register one more task on a live session.
    pub fn register_task(&self, task: TaskDecl) -> Result<()> {
        self.call_ok(ServiceRequest::RegisterTask { task })
    }

    /// `put_prompts_data`: batch prompt ingest; returns assigned indices.
    pub fn put_prompts_data(
        &self,
        prompts: &[Vec<i32>],
    ) -> Result<Vec<GlobalIndex>> {
        self.call_indices(ServiceRequest::PutPrompts {
            prompts: prompts.to_vec(),
        })
    }

    /// `put_experience_data`: single-cell write.
    pub fn put_experience_data(
        &self,
        index: GlobalIndex,
        column: Column,
        value: Value,
    ) -> Result<()> {
        self.call_ok(ServiceRequest::PutExperience {
            index,
            column,
            value,
        })
    }

    /// Batch-first write: many rows (new or existing) per round-trip.
    /// Returns one index per row, in order.
    pub fn put_batch(
        &self,
        rows: Vec<PutRow>,
    ) -> Result<Vec<GlobalIndex>> {
        self.call_indices(ServiceRequest::PutBatch { rows })
    }

    /// `get_experience_data`, batch-first, with deadline semantics:
    /// `NotReady` means retry, `Closed` means the stream is drained.
    pub fn get_batch(&self, spec: &GetBatchSpec) -> Result<GetBatchReply> {
        match self.call(ServiceRequest::GetBatch(spec.clone()))? {
            ServiceResponse::Batch(reply) => Ok(reply),
            _ => bail!("service returned an unexpected response kind"),
        }
    }

    /// Convenience loop over [`ServiceClient::get_batch`]: blocks until a
    /// batch is ready (`Some`) or the queue closes (`None`). Each retry
    /// long-polls for `spec.timeout_ms` (uses 50ms when the spec says 0,
    /// so the loop never spins hot).
    pub fn get_batch_blocking(
        &self,
        spec: &GetBatchSpec,
    ) -> Result<Option<Batch>> {
        self.get_batch_blocking_until(spec, || false)
    }

    /// Like [`ServiceClient::get_batch_blocking`] but aborts (returning
    /// `Ok(None)`) as soon as `abort()` turns true — the shutdown-aware
    /// worker loop.
    pub fn get_batch_blocking_until(
        &self,
        spec: &GetBatchSpec,
        abort: impl Fn() -> bool,
    ) -> Result<Option<Batch>> {
        let mut spec = spec.clone();
        if spec.timeout_ms == 0 {
            spec.timeout_ms = 50;
        }
        loop {
            if abort() {
                return Ok(None);
            }
            match self.get_batch(&spec)? {
                GetBatchReply::Ready(b) => return Ok(Some(b)),
                GetBatchReply::NotReady => continue,
                GetBatchReply::Closed => return Ok(None),
            }
        }
    }

    /// Long-poll for a weight snapshot newer than `min_version`.
    /// `Ok(None)` means nothing newer arrived before the timeout — the
    /// server elides the payload for "no change" answers, so polling is
    /// cheap even over TCP.
    pub fn subscribe_weights(
        &self,
        min_version: u64,
        timeout_ms: u64,
    ) -> Result<Option<ParamSet>> {
        match self.call(ServiceRequest::SubscribeWeights {
            min_version,
            timeout_ms,
        })? {
            ServiceResponse::Weights(p) => Ok(Some(p)),
            ServiceResponse::WeightsNotNewer { .. } => Ok(None),
            _ => bail!("service returned an unexpected response kind"),
        }
    }

    /// `weight_sync_notify`: publish a new weight snapshot.
    pub fn weight_sync_notify(&self, params: ParamSet) -> Result<()> {
        self.call_ok(ServiceRequest::WeightSync { params })
    }

    /// `lease_prompts`: lease ready prompt rows for an elastic rollout
    /// worker (server-side long-poll up to `spec.timeout_ms`). A reply
    /// without a lease means "nothing available right now" — poll
    /// again, unless `closed` says the stream is drained and nothing is
    /// in flight anywhere.
    pub fn lease_prompts(&self, spec: &LeaseSpec) -> Result<LeaseReply> {
        match self.call(ServiceRequest::LeasePrompts(spec.clone()))? {
            ServiceResponse::Lease(reply) => Ok(reply),
            _ => bail!("service returned an unexpected response kind"),
        }
    }

    /// `put_chunk`: stream partial generations for leased rows (implicit
    /// heartbeat). Rows flagged `finished` commit to the queue.
    pub fn put_chunk(
        &self,
        lease: LeaseId,
        version: u64,
        rows: Vec<ChunkRow>,
    ) -> Result<()> {
        self.call_ok(ServiceRequest::PutChunk { lease, version, rows })
    }

    /// `renew_lease`: explicit heartbeat. `ttl_ms = 0` keeps the TTL
    /// granted at lease time. An error means the lease expired — drop
    /// the in-flight batch and lease afresh.
    pub fn renew_lease(&self, lease: LeaseId, ttl_ms: u64) -> Result<()> {
        self.call_ok(ServiceRequest::RenewLease { lease, ttl_ms })
    }

    /// `worker_stats`: per-rollout-worker load/progress snapshot.
    pub fn worker_stats(&self) -> Result<Vec<WorkerStat>> {
        match self.call(ServiceRequest::WorkerStats)? {
            ServiceResponse::Workers(ws) => Ok(ws),
            _ => bail!("service returned an unexpected response kind"),
        }
    }

    /// Queue/param introspection.
    pub fn stats(&self) -> Result<ServiceStats> {
        match self.call(ServiceRequest::Stats)? {
            ServiceResponse::Stats(s) => Ok(s),
            _ => bail!("service returned an unexpected response kind"),
        }
    }

    /// Global-batch GC of fully consumed rows.
    pub fn evict(&self, indices: &[GlobalIndex]) -> Result<()> {
        self.call_ok(ServiceRequest::Evict { indices: indices.to_vec() })
    }

    /// Close the queue; consumers drain and observe `Closed`.
    pub fn shutdown(&self) -> Result<()> {
        self.call_ok(ServiceRequest::Shutdown)
    }
}
