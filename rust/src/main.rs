//! `asyncflow` — leader entrypoint + CLI.
//!
//! Subcommands:
//! * `train`    — run GRPO post-training on the real three-layer stack
//!   (AOT artifacts via PJRT) or the mock backend.
//! * `serve`    — expose a TransferQueue/ParamStore session as a TCP
//!   JSON-lines service (paper §5: the service-oriented interface, made
//!   a real process boundary).
//! * `rollout-worker` — attach an elastic rollout worker to a served
//!   session (`--connect host:port`): lease prompts, stream chunked
//!   generations, refresh weights at chunk boundaries.
//! * `stage` — attach one pipeline stage (reward grader, advantage,
//!   best-of-n filter) to a served session (`--connect host:port`):
//!   the stage loop speaks the same `get_batch`/`put_batch` verbs an
//!   in-process node uses, so reward models and filters scale out (or
//!   join mid-run) as separate processes.
//! * `storage-unit` — host one data-plane shard in this process and
//!   register it with a served session (`--connect host:port`): payload
//!   bytes then flow between clients and this unit over the binary
//!   frame codec, bypassing the coordinator socket (paper §3.2's
//!   distributed storage made a real process boundary).
//! * `simulate` — cluster-scale simulation (Fig. 10 / Table 1 modes).
//! * `chaos`    — preemption chaos harness: seeded OU spot-price kill
//!   schedule executed over a live multi-process run, with live
//!   invariant checks (lease conservation, exactly-once, weight
//!   convergence, throughput floor) and a `BENCH_chaos.json` report.
//! * `plan`     — resource planner (paper §4.3).
//! * `gantt`    — simulated execution timeline (Fig. 11).
//! * `info`     — artifact bundle + PJRT platform info, or (with
//!   `--connect`) a live service's queue/unit/worker statistics plus
//!   staleness/latency histograms and per-sample lineage counts.
//! * `trace`    — drain a live service's merged telemetry (coordinator
//!   spans + everything workers/stages/units pushed) as Chrome
//!   trace-event JSON for Perfetto / `chrome://tracing` (Fig. 11 from
//!   a real distributed run).

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use asyncflow::chaos::{run_chaos, ChaosOptions, ProcessKind};
use asyncflow::config::{ConfigDoc, RlConfig};
use asyncflow::coordinator::Trainer;
use asyncflow::exec::Shutdown;
use asyncflow::fleet::{EngineSpec, FleetOptions, RoutingPolicy};
use asyncflow::launcher::{build_engines, build_policy_engine};
use asyncflow::pipeline::{builtin_stage, run_remote_stage};
use asyncflow::planner::{plan, CostModel, DeviceSpec, LlmSpec, PlanRequest};
use asyncflow::rollout::{run_worker, WorkerOptions};
use asyncflow::runtime::{
    default_artifact_dir, Manifest, ParamSet, Sampler, XlaRuntime,
};
use asyncflow::service::{
    ServiceClient, Session, SessionSpec, TcpJsonlServer,
};
use asyncflow::simulator::{simulate, Mode, SimConfig};
use asyncflow::telemetry::chrome_trace;
use asyncflow::transfer_queue::{StorageUnit, UnitServer};
use asyncflow::{log_info, log_warn};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        log_warn!("cli", "{e:#}");
        std::process::exit(1);
    }
}

/// A token counts as a flag only if it is `--` followed by something
/// that is not a number — so negative values (`--offset -3`, or even the
/// degenerate `--3`) are always treated as values, never swallowed as
/// flags.
fn is_flag_token(tok: &str) -> bool {
    match tok.strip_prefix("--") {
        Some(rest) => !rest.is_empty() && rest.parse::<f64>().is_err(),
        None => false,
    }
}

/// Tiny flag parser: `--key value`, `--key=value`, and bare `--flag`
/// pairs after the subcommand.
fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if is_flag_token(&args[i]) {
            let body = args[i].strip_prefix("--").unwrap();
            if let Some((key, value)) = body.split_once('=') {
                flags.insert(key.to_string(), value.to_string());
            } else {
                let value = if i + 1 < args.len()
                    && !is_flag_token(&args[i + 1])
                {
                    i += 1;
                    args[i].clone()
                } else {
                    "true".to_string()
                };
                flags.insert(body.to_string(), value);
            }
        }
        i += 1;
    }
    flags
}

fn dispatch(args: &[String]) -> Result<()> {
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&args[1.min(args.len())..]);
    match cmd {
        "train" => cmd_train(&flags),
        "serve" => cmd_serve(&flags),
        "rollout-worker" => cmd_rollout_worker(&flags),
        "stage" => cmd_stage(&flags),
        "storage-unit" => cmd_storage_unit(&flags),
        "simulate" => cmd_simulate(&flags),
        "chaos" => cmd_chaos(&flags),
        "plan" => cmd_plan(&flags),
        "gantt" => cmd_gantt(&flags),
        "info" => cmd_info(&flags),
        "trace" => cmd_trace(&flags),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown subcommand {other:?} (try `asyncflow help`)"),
    }
}

const HELP: &str = "\
asyncflow — asynchronous streaming RL post-training (paper reproduction)

USAGE: asyncflow <command> [--flags]

COMMANDS:
  train     --iterations N --global-batch N --staleness {0|1} --mock
            --rollout-workers N --policy {fcfs|token_balanced|shortest_first}
            --pipeline {grpo|best_of_n} --survivors K --config file.toml
            --routing {lb|fallback|hedge|mirror}
  serve     --port N --storage-units N
            --policy {fcfs|token_balanced|shortest_first} --uninit
            --routing {lb|fallback|hedge|mirror}
            (JSON-lines service; clients attach with ServiceClient.
             --routing picks the engine-fleet policy over lease grants)
  rollout-worker --connect HOST:PORT [--name ID] [--mock] [--task T]
            [--chunk-tokens N] [--ttl-ms N] [--lease-rows N] [--seed N]
            [--engine-tags a,b,c] [--relay]
            (elastic worker: lease prompts, stream chunked generations;
             --engine-tags labels this engine in the fleet registry,
             e.g. fast-cheap or slow-accurate; --relay routes payloads
             through the coordinator so nothing strands on a dead unit)
  stage     --connect HOST:PORT --stage {reward|advantage|filter}
            [--task T] [--batch N] [--group-size G] [--survivors K]
            [--name ID] [--lease-ttl-ms N] [--relay]
            (attach a pipeline stage to a live run over TCP; a new
             input task is registered mid-run and replays resident
             rows. Batches are consumed under a consumer lease, so
             killing the stage mid-batch requeues its rows — 0
             disables leases)
  storage-unit --connect HOST:PORT [--slot N] [--listen HOST:PORT]
            [--advertise HOST:PORT]
            (host a data-plane shard: payload bytes bypass the
             coordinator socket; --slot defaults to the first
             unattached unit)
  simulate  --devices N --model {7b|32b} --mode {colocated|sequential|streaming|async|substep}
            --iterations N
  chaos     [--smoke] [--seed N] [--workers N] [--units N] [--stages N]
            [--horizon-ms N] [--warmup-ms N] [--min-events N]
            [--respawn-delay-ms N] [--elastic] [--quiet] [--out FILE]
            (preemption chaos harness: seeded OU spot-price kill
             schedule over a live multi-process run with live invariant
             checks — lease conservation, exactly-once, weight
             convergence, throughput floor. Writes BENCH_chaos.json;
             exits non-zero on any violation. --elastic recomputes the
             worker population from observed throughput via the
             planner)
  plan      --devices N --model {7b|32b}
  gantt     --devices N --model {7b|32b} --mode ... --width N
  info      [--connect HOST:PORT]  (live queue/unit/worker/fleet stats
            plus staleness / time-to-first-chunk histograms and lineage)
  trace     --connect HOST:PORT [--out FILE]
            (drain merged telemetry as Chrome trace-event JSON; load
             the output in Perfetto — one lane per process/stage)
";

fn get_usize(flags: &HashMap<String, String>, key: &str, default: usize)
    -> Result<usize>
{
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v.parse().with_context(|| format!("--{key} {v:?}")),
    }
}

fn model_by_name(name: &str) -> Result<LlmSpec> {
    Ok(match name {
        "7b" => LlmSpec::qwen_7b(),
        "32b" => LlmSpec::qwen_32b(),
        other => bail!("unknown model {other:?} (7b|32b)"),
    })
}

fn mode_by_name(name: &str) -> Result<Mode> {
    Ok(match name {
        "colocated" => Mode::Colocated,
        "sequential" => Mode::SeparatedSequential,
        "streaming" => Mode::SeparatedStreaming,
        "async" => Mode::SeparatedAsync,
        "substep" => Mode::SeparatedSubStep,
        other => bail!("unknown mode {other:?}"),
    })
}

fn cmd_train(flags: &HashMap<String, String>) -> Result<()> {
    let mut cfg = match flags.get("config") {
        Some(path) => RlConfig::from_doc(&ConfigDoc::load(path)?)?,
        None => RlConfig::default(),
    };
    cfg.iterations = get_usize(flags, "iterations", cfg.iterations)?;
    cfg.global_batch = get_usize(flags, "global-batch", cfg.global_batch)?;
    cfg.staleness =
        get_usize(flags, "staleness", cfg.staleness as usize)? as u64;
    cfg.rollout_workers =
        get_usize(flags, "rollout-workers", cfg.rollout_workers)?;
    if let Some(p) = flags.get("policy") {
        cfg.policy = p.clone();
    }
    if let Some(p) = flags.get("pipeline") {
        cfg.pipeline = p.clone();
    }
    cfg.survivors = get_usize(flags, "survivors", cfg.survivors)?;
    if let Some(r) = flags.get("routing") {
        cfg.fleet.routing = r.clone();
    }
    let mock = flags.contains_key("mock");
    let (engines, _b) = build_engines(&cfg, mock)?;
    log_info!(
        "train",
        "pipeline={} iterations={} global_batch={} staleness={} \
         workers={} backend={}",
        cfg.pipeline,
        cfg.iterations,
        cfg.global_batch,
        cfg.staleness,
        cfg.rollout_workers,
        if mock { "mock" } else { "xla-pjrt" }
    );
    let report = Trainer::new(cfg, engines)?.run()?;
    println!(
        "[train] done: {} iterations, {} samples, {:.1} samples/s, \
         {:.0} tokens/s, final reward {:.3}",
        report.iterations,
        report.samples_trained,
        report.throughput_samples_per_s(),
        report.throughput_tokens_per_s(),
        report.final_reward,
    );
    Ok(())
}

/// `asyncflow serve`: front a TransferQueue/ParamStore session with the
/// TCP JSON-lines transport so external trainers and rollout workers can
/// attach from other processes/hosts (paper §5 made literal).
fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let port = get_usize(flags, "port", 7740)? as u16;
    let session = if flags.contains_key("uninit") {
        // Empty session: the first client sends the init_engines verb.
        Arc::new(Session::new())
    } else {
        let storage_units = get_usize(flags, "storage-units", 2)?;
        let policy = flags
            .get("policy")
            .map(String::as_str)
            .unwrap_or("fcfs");
        Arc::new(Session::init_engines(
            SessionSpec::grpo_with_policy(storage_units, policy),
            ParamSet::new(0, vec![]),
        )?)
    };
    if let Some(r) = flags.get("routing") {
        session.set_fleet_options(FleetOptions {
            policy: RoutingPolicy::parse(r)?,
            ..FleetOptions::default()
        });
        log_info!("serve", "fleet routing policy: {r}");
    }
    let server =
        TcpJsonlServer::bind(session, ("0.0.0.0", port))?;
    log_info!(
        "serve",
        "asyncflow service listening on {} (JSONL protocol; see \
         DESIGN.md §Wire protocol)",
        server.local_addr()
    );
    server.join();
    Ok(())
}

/// `asyncflow rollout-worker`: join a served session's elastic rollout
/// pool from another process/host. The worker leases prompt groups,
/// decodes them in bounded chunks, streams partial generations back, and
/// picks up published weights at chunk boundaries. If it crashes or
/// stalls, the coordinator requeues its prompts after the lease TTL.
fn cmd_rollout_worker(flags: &HashMap<String, String>) -> Result<()> {
    let addr = flags
        .get("connect")
        .context("--connect HOST:PORT is required")?;
    let mock = flags.contains_key("mock");
    let name = flags
        .get("name")
        .cloned()
        .unwrap_or_else(|| format!("worker-{}", std::process::id()));
    let mut engine = build_policy_engine(mock)?;
    let mut opts = WorkerOptions::new(name.clone());
    if let Some(task) = flags.get("task") {
        opts.task = task.clone();
    }
    opts.chunk_tokens =
        get_usize(flags, "chunk-tokens", opts.chunk_tokens)?;
    opts.ttl_ms = get_usize(flags, "ttl-ms", opts.ttl_ms as usize)? as u64;
    opts.lease_rows =
        get_usize(flags, "lease-rows", engine.batch_size())?;
    if let Some(tags) = flags.get("engine-tags") {
        opts.engine_tags = EngineSpec::parse_tags(tags);
    }
    let seed =
        get_usize(flags, "seed", std::process::id() as usize)? as u64;
    let mut sampler = Sampler::new(1.0, 32, seed);
    // --relay: route payload bytes through the coordinator instead of
    // writing directly to storage units. Slower, but nothing is ever
    // stranded on a dead unit — the mode chaos runs use.
    let client = if flags.contains_key("relay") {
        ServiceClient::connect_relay(addr.as_str())?
    } else {
        ServiceClient::connect(addr.as_str())?
    };
    log_info!(
        &name,
        "attached to {addr} (backend={}, chunk={} tokens, ttl={}ms)",
        if mock { "mock" } else { "xla-pjrt" },
        opts.chunk_tokens,
        opts.ttl_ms
    );
    let report = run_worker(
        &client,
        engine.as_mut(),
        &mut sampler,
        &opts,
        None,
        None,
        &|| false,
    )?;
    println!(
        "[rollout-worker] {name}: stream closed — {} samples, {} tokens, \
         {} chunks, {} weight swaps, {} leases lost",
        report.samples,
        report.tokens,
        report.chunks,
        report.weight_swaps,
        report.leases_lost
    );
    Ok(())
}

/// `asyncflow stage`: attach one pipeline stage to a served session
/// from another process/host. The stage pulls micro-batches from its
/// input task, processes them, and writes result columns back — the
/// byte-identical loop an in-process `PipelineRunner` node runs, over
/// TCP. Attaching a stage whose input task the session lacks registers
/// it mid-run (resident rows replay). Attaching `reward` to an
/// existing task scales grading out (rows are consumed exactly once
/// across all competing workers); `advantage`/`filter` hold
/// per-instance group state, so run them only as the sole consumer of
/// their task (competing instances would split groups and stall the
/// graph). If the stage fails, the whole graph is drained before the
/// error propagates; if it is killed outright (`kill -9`), its
/// consumer leases are revoked — on disconnect, or at `--lease-ttl-ms`
/// as the backstop — and its in-flight rows requeue to the surviving
/// consumers, so no data is ever stranded.
fn cmd_stage(flags: &HashMap<String, String>) -> Result<()> {
    let addr = flags
        .get("connect")
        .context("--connect HOST:PORT is required")?;
    let which = flags
        .get("stage")
        .context("--stage NAME is required (reward|advantage|filter)")?;
    let group_size = get_usize(flags, "group-size", 4)?;
    let survivors = get_usize(flags, "survivors", 1)?;
    let (mut input, mut stage) =
        builtin_stage(which, group_size, survivors)?;
    input.count = get_usize(flags, "batch", input.count)?;
    if let Some(task) = flags.get("task") {
        input.task = task.clone();
    }
    input.lease_ttl_ms = get_usize(
        flags,
        "lease-ttl-ms",
        input.lease_ttl_ms as usize,
    )? as u64;
    let name = flags
        .get("name")
        .cloned()
        .unwrap_or_else(|| format!("{which}-{}", std::process::id()));
    let client = if flags.contains_key("relay") {
        ServiceClient::connect_relay(addr.as_str())?
    } else {
        ServiceClient::connect(addr.as_str())?
    };
    log_info!(
        &name,
        "attached to {addr} (stage {which}, task {:?}, batch {}, \
         lease ttl {}ms)",
        input.task, input.count, input.lease_ttl_ms
    );
    let metrics = run_remote_stage(
        &client,
        &name,
        Some(&input),
        stage.as_mut(),
        &Shutdown::new(),
    )?;
    // Stage metrics live in THIS process (the coordinator's report
    // only covers its own nodes) — surface what this worker did.
    let mut summary: Vec<String> = Vec::new();
    for series in metrics.series_names() {
        if let Some(s) = metrics.series(&series) {
            summary.push(format!(
                "{series}: n={} mean={:.4}",
                s.points.len(),
                s.mean()
            ));
        }
    }
    println!(
        "[stage] {name}: stream closed, exiting{}{}",
        if summary.is_empty() { "" } else { " — " },
        summary.join(", ")
    );
    Ok(())
}

/// `asyncflow storage-unit`: host one data-plane shard in this process.
/// Binds a binary-frame payload server, registers it with the served
/// session (`attach_unit`), and serves until killed. Resident shard
/// payloads are migrated over by the coordinator on attach; if this
/// process dies, the coordinator detaches the slot and serves its local
/// replica (clients fall back through the coordinator transparently).
fn cmd_storage_unit(flags: &HashMap<String, String>) -> Result<()> {
    let addr = flags
        .get("connect")
        .context("--connect HOST:PORT is required")?;
    let listen = flags
        .get("listen")
        .cloned()
        .unwrap_or_else(|| "0.0.0.0:0".to_string());
    let client = ServiceClient::connect_relay(addr.as_str())?;
    let slot = match flags.get("slot") {
        Some(s) => s.parse().with_context(|| format!("--slot {s:?}"))?,
        None => {
            // First unit without an attached endpoint. Racing
            // storage-unit processes resolve via attach_unit failing
            // for the loser — rerun with an explicit --slot.
            let stats = client.stats()?;
            stats
                .units
                .iter()
                .find(|u| u.endpoint.is_none())
                .map(|u| u.unit)
                .context("no unattached storage-unit slot left")?
        }
    };
    let store = Arc::new(StorageUnit::new(slot));
    let server = UnitServer::bind(store, listen.as_str())?;
    let advertise = flags.get("advertise").cloned().unwrap_or_else(|| {
        // 0.0.0.0 binds are not dialable; advertise loopback for the
        // single-host default.
        let ip = server.local_addr().ip();
        if ip.is_unspecified() {
            format!("127.0.0.1:{}", server.port())
        } else {
            server.local_addr().to_string()
        }
    });
    client.attach_unit(slot, &advertise)?;
    log_info!(
        "storage-unit",
        "slot {slot}: payload shard on {} (advertised {advertise}, \
         coordinator {addr}; binary frame codec — see DESIGN.md \
         §Payload wire)",
        server.local_addr()
    );
    // Ship this process's `unit_put` spans to the coordinator so the
    // merged `asyncflow trace` timeline gets a storage-unit track.
    // Best-effort on a slow cadence: push_telemetry drains our span
    // log either way and swallows old-server errors.
    let proc = format!("storage-unit-{slot}");
    std::thread::spawn(move || loop {
        std::thread::sleep(Duration::from_secs(5));
        client.push_telemetry(&proc);
    });
    server.join();
    Ok(())
}

/// `asyncflow chaos`: preemption-trace-driven chaos harness. Generates
/// a seeded Ornstein–Uhlenbeck spot-price kill schedule over rollout
/// workers, storage units, and TCP stages; re-execs the full topology
/// as child processes (relay mode); executes the schedule with SIGKILL;
/// and checks lease conservation, exactly-once accounting, weight
/// convergence, and the throughput floor live between events. Writes
/// the machine-readable report to `BENCH_chaos.json` (CI gates on it)
/// and exits non-zero on any violation.
fn cmd_chaos(flags: &HashMap<String, String>) -> Result<()> {
    let exe = std::env::current_exe()
        .context("resolving the asyncflow binary for child processes")?;
    let mut opts = if flags.contains_key("smoke") {
        ChaosOptions::smoke(exe)
    } else {
        ChaosOptions::new(exe)
    };
    opts.seed = get_usize(flags, "seed", opts.seed as usize)? as u64;
    opts.workers = get_usize(flags, "workers", opts.workers)?;
    opts.units = get_usize(flags, "units", opts.units)?;
    opts.stages = get_usize(flags, "stages", opts.stages)?;
    opts.horizon_ms =
        get_usize(flags, "horizon-ms", opts.horizon_ms as usize)? as u64;
    opts.warmup_ms =
        get_usize(flags, "warmup-ms", opts.warmup_ms as usize)? as u64;
    opts.min_events = get_usize(flags, "min-events", opts.min_events)?;
    opts.respawn_delay_ms = get_usize(
        flags,
        "respawn-delay-ms",
        opts.respawn_delay_ms as usize,
    )? as u64;
    opts.elastic = flags.contains_key("elastic");
    opts.quiet = flags.contains_key("quiet");
    let report = run_chaos(&opts)?;
    let out = flags
        .get("out")
        .cloned()
        .unwrap_or_else(|| "BENCH_chaos.json".to_string());
    std::fs::write(&out, report.to_json().to_string_pretty())
        .with_context(|| format!("writing {out}"))?;
    let p50 = report
        .recovery_p50_ms()
        .map_or_else(|| "-".into(), |v| format!("{v}ms"));
    let p99 = report
        .recovery_p99_ms()
        .map_or_else(|| "-".into(), |v| format!("{v}ms"));
    println!(
        "[chaos] seed {}: {} kills ({} worker / {} unit / {} stage, \
         {} skipped), recovery p50 {p50} p99 {p99}, throughput \
         {:.1} -> {:.1} samples/s (ratio {:.2}), {}/{} rows trained, \
         {} violations -> {out}",
        report.seed,
        report.kills.len(),
        report.kills_of(ProcessKind::Worker),
        report.kills_of(ProcessKind::Unit),
        report.kills_of(ProcessKind::Stage),
        report.events_skipped,
        report.baseline_sps,
        report.disturbed_sps,
        report.floor_ratio,
        report.rows_trained,
        report.rows_fed,
        report.violations.len()
    );
    for v in &report.violations {
        log_warn!("chaos", "violation: {v}");
    }
    if !report.passed() {
        bail!(
            "chaos run tripped {} invariant violation(s)",
            report.violations.len()
        );
    }
    Ok(())
}

fn cmd_simulate(flags: &HashMap<String, String>) -> Result<()> {
    let devices = get_usize(flags, "devices", 256)?;
    let model = model_by_name(
        flags.get("model").map(String::as_str).unwrap_or("7b"),
    )?;
    let mode = mode_by_name(
        flags.get("mode").map(String::as_str).unwrap_or("async"),
    )?;
    let mut cfg = SimConfig::defaults(devices, mode);
    cfg.iterations = get_usize(flags, "iterations", cfg.iterations)?;
    let cost = CostModel::new(DeviceSpec::ascend_910b(), model.clone());
    let r = simulate(&cfg, &cost);
    println!(
        "[simulate] {} devices={} model={} -> {:.2} samples/s, \
         {:.0} tokens/s, utilization {:.1}%, makespan {:.1}s",
        mode.label(),
        devices,
        model.name,
        r.throughput_samples_per_s(),
        r.throughput_tokens_per_s(),
        100.0 * r.utilization,
        r.makespan_s
    );
    Ok(())
}

fn cmd_plan(flags: &HashMap<String, String>) -> Result<()> {
    let devices = get_usize(flags, "devices", 256)?;
    let model = model_by_name(
        flags.get("model").map(String::as_str).unwrap_or("7b"),
    )?;
    let cost = CostModel::new(DeviceSpec::ascend_910b(), model.clone());
    let req = PlanRequest::new(devices);
    let p = plan(&req, &cost);
    println!(
        "[plan] {} on {} devices: rollout_fraction={:.3} \
         rollout_inst={} train_inst={} micro_batch={} -> {:.2} samples/s \
         ({} candidates evaluated)",
        model.name,
        devices,
        p.best.rollout_fraction,
        p.best.rollout_instance_devices,
        p.best.train_instance_devices,
        p.best.micro_batch,
        p.best.throughput_samples_per_s,
        p.evaluated.len()
    );
    Ok(())
}

fn cmd_gantt(flags: &HashMap<String, String>) -> Result<()> {
    let devices = get_usize(flags, "devices", 512)?;
    let width = get_usize(flags, "width", 100)?;
    let model = model_by_name(
        flags.get("model").map(String::as_str).unwrap_or("32b"),
    )?;
    let mode = mode_by_name(
        flags.get("mode").map(String::as_str).unwrap_or("async"),
    )?;
    let mut cfg = SimConfig::defaults(devices, mode);
    cfg.iterations = get_usize(flags, "iterations", 4)?;
    let cost = CostModel::new(DeviceSpec::ascend_910b(), model);
    let r = simulate(&cfg, &cost);
    println!("{}", r.timeline.render_ascii(width));
    Ok(())
}

fn cmd_info(flags: &HashMap<String, String>) -> Result<()> {
    // With --connect, report a live service instead of local artifacts:
    // per-task queue depths, per-storage-unit occupancy/traffic (load
    // imbalance is visible over the wire), and per-rollout-worker load.
    if let Some(addr) = flags.get("connect") {
        let client = ServiceClient::connect(addr.as_str())?;
        let stats = client.stats()?;
        println!(
            "service {addr}: param_version={} resident_rows={} closed={}",
            stats.param_version, stats.resident_rows, stats.closed
        );
        for t in &stats.tasks {
            println!(
                "  task {:<12} ready={:<6} leased={:<5} consumed={:<8} \
                 policy={} waiting={} oldest_ready={}",
                t.name,
                t.ready,
                t.leased,
                t.consumed,
                t.policy,
                t.waiting_consumers,
                t.oldest_ready_age_ms
                    .map(|ms| format!("{ms}ms"))
                    .unwrap_or_else(|| "-".into()),
            );
        }
        for u in &stats.units {
            match &u.endpoint {
                Some(ep) => println!(
                    "  unit {:<3} rows={:<6} written={}B read={}B \
                     attached@{ep} remote_written={}B remote_read={}B",
                    u.unit,
                    u.rows,
                    u.bytes_written,
                    u.bytes_read,
                    u.remote_bytes_written,
                    u.remote_bytes_read
                ),
                None => println!(
                    "  unit {:<3} rows={:<6} written={}B read={}B local",
                    u.unit, u.rows, u.bytes_written, u.bytes_read
                ),
            }
        }
        for w in &client.worker_stats()? {
            println!(
                "  worker {:<12} leases={} in_flight={} completed={} \
                 tokens={} requeued={}",
                w.worker,
                w.active_leases,
                w.in_flight_rows,
                w.completed_rows,
                w.generated_tokens,
                w.requeued_rows
            );
        }
        // Fleet section: the engine registry plus routing counters.
        // Older coordinators elide it.
        if let Some(f) = &stats.fleet {
            println!(
                "  fleet routing={} chunk_p50={:.1}ms chunk_p95={:.1}ms \
                 hedge_budget={:.1}ms",
                f.routing,
                f.chunk_time_p50_ms,
                f.chunk_time_p95_ms,
                f.hedge_budget_ms
            );
            for e in &f.engines {
                println!(
                    "    engine {:<12} kind={:<8} speed={:<8} \
                     geometry={}x{}->{} tags=[{}] src={} chunks={} \
                     tokens={} errors={} tps={:.0}",
                    e.worker,
                    e.spec.kind,
                    e.spec.speed.name(),
                    e.spec.batch,
                    e.spec.prompt_len,
                    e.spec.max_len,
                    e.spec.tags.join(","),
                    e.source,
                    e.chunks,
                    e.tokens,
                    e.errors,
                    e.observed_tps
                );
            }
            if f.hedges_issued + f.mirrors_issued + f.lb_deferrals
                + f.fallback_requeues
                > 0
            {
                println!(
                    "    routing hedges={} (won_by_dup={} won_by_primary={} \
                     dup_tokens={}) mirrors={} (match={} diverge={}) \
                     lb_deferrals={} fallback_requeues={}",
                    f.hedges_issued,
                    f.hedge_rows_won_by_duplicate,
                    f.hedge_rows_won_by_primary,
                    f.duplicated_tokens,
                    f.mirrors_issued,
                    f.mirror_matches,
                    f.mirror_divergences,
                    f.lb_deferrals,
                    f.fallback_requeues
                );
            }
        }
        // Telemetry aggregates: staleness / latency histograms and the
        // per-sample lineage table. Best-effort — an older coordinator
        // without the export_telemetry verb just skips this section.
        if let Ok(snap) = client.export_telemetry(None) {
            if let Some(coord) =
                snap.procs.iter().find(|p| p.proc == "coordinator")
            {
                for (name, h) in &coord.hists {
                    println!(
                        "  hist {name:<24} n={:<6} p50={:.1} p95={:.1} \
                         p99={:.1} max={:.1}",
                        h.count, h.p50, h.p95, h.p99, h.max
                    );
                }
            }
            if !snap.lineage.is_empty() {
                let complete = snap
                    .lineage
                    .iter()
                    .filter(|r| r.complete())
                    .count();
                println!(
                    "  lineage rows={} complete={}",
                    snap.lineage.len(),
                    complete
                );
            }
        }
        // Control-plane section: only servers running the multiplexed
        // (or instrumented threaded) TCP front end report it.
        if let Some(c) = &stats.control {
            println!(
                "  control connections={} verbs={} verbs/s={:.1} \
                 parked_long_polls={}",
                c.connections,
                c.verbs_total,
                c.verbs_per_sec,
                c.parked_long_polls
            );
            for (op, n) in &c.verbs_by_op {
                println!("    verb {op:<22} {n}");
            }
            let labels = ["1", "2", "4", "8", "16", "32", "33+"];
            let depths: Vec<String> = c
                .pipelined_depth
                .iter()
                .zip(labels.iter())
                .filter(|(n, _)| **n > 0)
                .map(|(n, l)| format!("<={l}:{n}"))
                .collect();
            if !depths.is_empty() {
                println!("    pipelined depth {}", depths.join(" "));
            }
        }
        if let Some(w) = &stats.weights {
            println!(
                "  weights version={} tensors={} full={}B delta={}B \
                 unit_push={}B",
                w.published_version,
                w.tensors,
                w.full_payload_bytes,
                w.delta_payload_bytes,
                w.unit_push_bytes
            );
            for s in &w.subscribers {
                println!(
                    "    subscriber {:<12} at_version={} lag={}",
                    s.id,
                    s.version,
                    w.published_version.saturating_sub(s.version)
                );
            }
        }
        return Ok(());
    }
    let dir = default_artifact_dir();
    match Manifest::load(&dir) {
        Ok(m) => {
            println!(
                "artifacts: preset={} params={} batch={} prompt={} max={}",
                m.preset,
                m.model.param_count,
                m.model.batch,
                m.model.prompt_len,
                m.model.max_len
            );
            for (name, a) in &m.artifacts {
                println!(
                    "  {name}: {} args -> {} results ({})",
                    a.args.len(),
                    a.results.len(),
                    a.path.display()
                );
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    match XlaRuntime::cpu() {
        Ok(rt) => println!(
            "pjrt: platform={} devices={}",
            rt.platform(),
            rt.device_count()
        ),
        Err(e) => println!("pjrt: unavailable ({e})"),
    }
    Ok(())
}

/// `asyncflow trace`: drain a live service's merged telemetry (the
/// coordinator's spans plus everything workers, stages, and storage
/// units pushed) and render it as Chrome trace-event JSON. Load the
/// output in Perfetto or `chrome://tracing` for the paper's Fig. 11
/// view of a real distributed run. Draining is destructive by design:
/// a second call returns only spans recorded in between.
fn cmd_trace(flags: &HashMap<String, String>) -> Result<()> {
    let addr = flags
        .get("connect")
        .context("--connect HOST:PORT is required")?;
    let client = ServiceClient::connect(addr.as_str())?;
    let snap = client.export_telemetry(None)?;
    let spans: usize = snap.procs.iter().map(|p| p.spans.len()).sum();
    let json = chrome_trace(&snap).to_string();
    match flags.get("out") {
        Some(path) => {
            std::fs::write(path, json.as_bytes())
                .with_context(|| format!("writing {path}"))?;
            log_info!(
                "trace",
                "wrote {spans} spans from {} processes ({} lineage \
                 rows) to {path}",
                snap.procs.len(),
                snap.lineage.len()
            );
        }
        None => println!("{json}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(toks: &[&str]) -> Vec<String> {
        toks.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_flags_key_value_pairs_and_booleans() {
        let f = parse_flags(&args(&[
            "--iterations", "5", "--mock", "--policy", "fcfs",
        ]));
        assert_eq!(f.get("iterations").unwrap(), "5");
        assert_eq!(f.get("mock").unwrap(), "true");
        assert_eq!(f.get("policy").unwrap(), "fcfs");
        assert_eq!(f.len(), 3);
    }

    #[test]
    fn parse_flags_accepts_negative_values() {
        let f = parse_flags(&args(&["--offset", "-3", "--lr", "-1.5e-4"]));
        assert_eq!(f.get("offset").unwrap(), "-3");
        assert_eq!(f.get("lr").unwrap(), "-1.5e-4");
        // a numeric token is never mis-parsed as a flag key
        assert!(!f.contains_key("3"));
    }

    #[test]
    fn parse_flags_equals_syntax() {
        let f = parse_flags(&args(&["--offset=-3", "--name=x=y"]));
        assert_eq!(f.get("offset").unwrap(), "-3");
        // split on the FIRST '=' only
        assert_eq!(f.get("name").unwrap(), "x=y");
    }

    #[test]
    fn parse_flags_trailing_flag_is_boolean() {
        let f = parse_flags(&args(&["--port", "7740", "--uninit"]));
        assert_eq!(f.get("port").unwrap(), "7740");
        assert_eq!(f.get("uninit").unwrap(), "true");
    }

    #[test]
    fn parse_flags_numeric_like_flag_treated_as_value() {
        // `--3` parses as a number, so it is a value, not a flag key.
        let f = parse_flags(&args(&["--offset", "--3"]));
        assert_eq!(f.get("offset").unwrap(), "--3");
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn parse_flags_ignores_loose_positional_tokens() {
        let f = parse_flags(&args(&["stray", "--k", "v", "loose"]));
        assert_eq!(f.get("k").unwrap(), "v");
        assert_eq!(f.len(), 1);
    }
}
