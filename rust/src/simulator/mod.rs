//! Cluster simulator — reproduces the paper's large-scale evaluation
//! (Fig. 10 scalability, Table 1 ablation, Fig. 11 Gantt) by executing
//! the coordinator's scheduling policies over the §4.3 analytic cost
//! model at 32–1024-NPU scale. See DESIGN.md §Substitutions.

pub mod sim;
pub mod workload;

pub use sim::{simulate, Mode, SimConfig, SimResult};
pub use workload::{generate_iteration, MicroBatch, SimSample, WorkloadSpec};
