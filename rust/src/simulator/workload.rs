//! Workload model: RL post-training sample streams with long-tailed
//! response lengths (the skew that motivates TransferQueue's dynamic
//! load balancing — paper §3.3/§7.3).

use crate::util::rng::Rng;

/// Distribution of one iteration's samples.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub prompt_len: usize,
    /// Median response length (lognormal median = exp(mu)).
    pub median_response: usize,
    /// Log-space sigma (tail heaviness). 0.0 = deterministic lengths.
    pub sigma: f64,
    pub max_response: usize,
    pub min_response: usize,
}

impl WorkloadSpec {
    /// Reasoning-RL workload in the DeepScaleR regime.
    pub fn reasoning() -> Self {
        WorkloadSpec {
            prompt_len: 512,
            median_response: 1024,
            sigma: 0.9,
            max_response: 6144,
            min_response: 32,
        }
    }

    pub fn sample_response_len(&self, rng: &mut Rng) -> usize {
        if self.sigma == 0.0 {
            return self.median_response;
        }
        let mu = (self.median_response as f64).ln();
        let len = rng.lognormal(mu, self.sigma);
        (len as usize).clamp(self.min_response, self.max_response)
    }
}

/// One simulated sample.
#[derive(Debug, Clone, Copy)]
pub struct SimSample {
    pub response_len: usize,
}

/// A micro-batch of samples; rollout time is governed by the *longest*
/// member (batched decode runs until the last sequence finishes).
#[derive(Debug, Clone)]
pub struct MicroBatch {
    pub samples: Vec<SimSample>,
}

impl MicroBatch {
    pub fn max_response(&self) -> usize {
        self.samples.iter().map(|s| s.response_len).max().unwrap_or(0)
    }

    pub fn total_tokens(&self) -> usize {
        self.samples.iter().map(|s| s.response_len).sum()
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
}

/// Generate one iteration's micro-batches.
pub fn generate_iteration(
    spec: &WorkloadSpec,
    global_batch: usize,
    micro_batch: usize,
    rng: &mut Rng,
) -> Vec<MicroBatch> {
    assert!(micro_batch > 0 && global_batch % micro_batch == 0);
    (0..global_batch / micro_batch)
        .map(|_| MicroBatch {
            samples: (0..micro_batch)
                .map(|_| SimSample {
                    response_len: spec.sample_response_len(rng),
                })
                .collect(),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lengths_respect_bounds() {
        let spec = WorkloadSpec::reasoning();
        let mut rng = Rng::new(0);
        for _ in 0..1000 {
            let l = spec.sample_response_len(&mut rng);
            assert!((spec.min_response..=spec.max_response).contains(&l));
        }
    }

    #[test]
    fn zero_sigma_is_deterministic() {
        let spec = WorkloadSpec { sigma: 0.0, ..WorkloadSpec::reasoning() };
        let mut rng = Rng::new(1);
        assert_eq!(spec.sample_response_len(&mut rng), 1024);
    }

    #[test]
    fn distribution_is_long_tailed() {
        let spec = WorkloadSpec::reasoning();
        let mut rng = Rng::new(2);
        let lens: Vec<usize> =
            (0..5000).map(|_| spec.sample_response_len(&mut rng)).collect();
        let mean = lens.iter().sum::<usize>() as f64 / lens.len() as f64;
        let mut sorted = lens.clone();
        sorted.sort_unstable();
        let median = sorted[lens.len() / 2] as f64;
        assert!(mean > median, "lognormal: mean {mean} > median {median}");
        assert!(*sorted.last().unwrap() > 3000, "tail exists");
    }

    #[test]
    fn iteration_partitioning() {
        let spec = WorkloadSpec::reasoning();
        let mut rng = Rng::new(3);
        let mbs = generate_iteration(&spec, 64, 16, &mut rng);
        assert_eq!(mbs.len(), 4);
        assert!(mbs.iter().all(|m| m.len() == 16));
        assert!(mbs[0].max_response() >= mbs[0].samples[0].response_len);
        assert_eq!(
            mbs[0].total_tokens(),
            mbs[0].samples.iter().map(|s| s.response_len).sum::<usize>()
        );
    }
}
