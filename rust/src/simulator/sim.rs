//! Discrete-event cluster simulator: executes the *same scheduling
//! policies* as the real coordinator over the analytic cost model, at
//! cluster scales this testbed cannot host (32–1024 NPUs). Reproduces the
//! paper's large-scale evaluation: Fig. 10 (scalability), Table 1
//! (ablation), Fig. 11 (Gantt).
//!
//! The simulation is micro-batch-granular list scheduling on a virtual
//! clock: rollout instances produce micro-batches (dynamic pull when
//! TransferQueue is enabled, static pre-assignment otherwise), the train
//! cluster consumes them through a reference-scoring + update path, and
//! iteration boundaries apply the configured synchronization rule
//! (sequential / on-policy streaming / one-step-async delayed update).

use crate::coordinator::Timeline;
use crate::planner::cost_model::CostModel;
use crate::util::rng::Rng;

use super::workload::{generate_iteration, WorkloadSpec};

/// Execution paradigm under simulation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// verl-like task-colocated baseline: every phase uses all devices
    /// sequentially, with resharding between rollout and train layouts.
    Colocated,
    /// Task-separated, no TransferQueue: stage barriers within an
    /// iteration, static sample pre-assignment (Table 1 row 1).
    SeparatedSequential,
    /// + TransferQueue streaming overlap, on-policy sync (Table 1 row 2).
    SeparatedStreaming,
    /// + asynchronous workflow: one-step staleness, delayed parameter
    /// update, overlapped weight transfer (Table 1 row 3 / AsyncFlow).
    SeparatedAsync,
    /// Paper §4.2.2 / Fig. 8(d) future-work mechanism: rollout instances
    /// swap weights *sequentially* (staggered), so generation capacity
    /// never drops to zero at a version boundary and staleness falls
    /// below one full step.
    SeparatedSubStep,
}

impl Mode {
    pub fn label(&self) -> &'static str {
        match self {
            Mode::Colocated => "verl-colocated",
            Mode::SeparatedSequential => "separated-sequential",
            Mode::SeparatedStreaming => "separated+TQ",
            Mode::SeparatedAsync => "separated+TQ+async",
            Mode::SeparatedSubStep => "separated+TQ+substep",
        }
    }
}

/// Full simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub devices: usize,
    pub mode: Mode,
    /// Fraction of devices assigned to rollout (separated modes).
    pub rollout_fraction: f64,
    /// Devices per rollout instance (inference TP/PP group).
    pub rollout_instance_devices: usize,
    /// Devices per train DP group.
    pub train_instance_devices: usize,
    pub global_batch: usize,
    pub micro_batch: usize,
    pub iterations: usize,
    pub workload: WorkloadSpec,
    pub seed: u64,
}

impl SimConfig {
    pub fn defaults(devices: usize, mode: Mode) -> Self {
        SimConfig {
            devices,
            mode,
            rollout_fraction: 0.65,
            rollout_instance_devices: 8,
            train_instance_devices: 8,
            global_batch: 2048,
            micro_batch: 16,
            iterations: 8,
            workload: WorkloadSpec::reasoning(),
            seed: 0,
        }
    }

    pub fn rollout_devices(&self) -> usize {
        ((self.devices as f64 * self.rollout_fraction) as usize).max(1)
    }

    pub fn train_devices(&self) -> usize {
        (self.devices - self.rollout_devices()).max(1)
    }

    pub fn n_rollout_instances(&self) -> usize {
        (self.rollout_devices() / self.rollout_instance_devices).max(1)
    }

    pub fn n_train_instances(&self) -> usize {
        (self.train_devices() / self.train_instance_devices).max(1)
    }
}

/// Simulation outcome.
pub struct SimResult {
    pub mode: Mode,
    pub devices: usize,
    pub makespan_s: f64,
    pub samples: usize,
    pub tokens: usize,
    pub timeline: Timeline,
    /// Mean busy fraction across all instances over the makespan.
    pub utilization: f64,
}

impl SimResult {
    pub fn throughput_samples_per_s(&self) -> f64 {
        self.samples as f64 / self.makespan_s.max(1e-12)
    }

    pub fn throughput_tokens_per_s(&self) -> f64 {
        self.tokens as f64 / self.makespan_s.max(1e-12)
    }

    pub fn bubble_fraction(&self) -> f64 {
        1.0 - self.utilization
    }
}

/// Run one simulation.
pub fn simulate(cfg: &SimConfig, cost: &CostModel) -> SimResult {
    match cfg.mode {
        Mode::Colocated => simulate_colocated(cfg, cost),
        _ => simulate_separated(cfg, cost),
    }
}

// ---------------------------------------------------------------------------
// Colocated (verl-like) baseline
// ---------------------------------------------------------------------------

fn simulate_colocated(cfg: &SimConfig, cost: &CostModel) -> SimResult {
    let timeline = Timeline::new();
    let mut rng = Rng::new(cfg.seed);
    let n = cfg.devices;
    // Colocated engines pay memory-pressure penalties: train MFU drops
    // (offload traffic), and decode throughput drops harder (KV-cache
    // memory shared with resident training states).
    let mut roll_cost = cost.clone();
    roll_cost.calib_rollout /= cost.mfu.colocated_decode_factor;
    let mut train_cost = cost.clone();
    train_cost.calib_train /= cost.mfu.colocated_factor;
    let cost_reshard = cost;
    let seq = cfg.workload.prompt_len + cfg.workload.median_response;

    // Rollout inside the colocated allocation still runs as TP-bounded
    // inference instances (verl's hybrid engine), not one giant TP group.
    let inst_dev = cfg.rollout_instance_devices.min(n).max(1);
    let n_inst = (n / inst_dev).max(1);

    let mut clock = 0.0f64;
    let mut samples = 0usize;
    let mut tokens = 0usize;
    for iter in 0..cfg.iterations {
        let mbs = generate_iteration(
            &cfg.workload,
            cfg.global_batch,
            cfg.micro_batch,
            &mut rng,
        );
        let it = format!("i{iter}");
        // reshard train layout -> inference layout
        let t = cost_reshard.reshard_time(n) * 0.3; // 3D-HybridEngine
        timeline.record("cluster", &format!("{it}:reshard"), clock,
                        clock + t);
        clock += t;
        // rollout: micro-batches spread over the inference instances;
        // the phase ends when the slowest instance finishes (all devices
        // are held until then — colocated phases are exclusive).
        let mut inst_busy = vec![0.0f64; n_inst];
        for (k, mb) in mbs.iter().enumerate() {
            let t = roll_cost.rollout_time(
                inst_dev,
                mb.len(),
                cfg.workload.prompt_len,
                mb.max_response(),
            );
            inst_busy[k % n_inst] += t;
            samples += mb.len();
            tokens += mb.total_tokens();
        }
        let gen_time =
            inst_busy.iter().copied().fold(0.0f64, f64::max);
        timeline.record("cluster", &format!("{it}:gen"), clock,
                        clock + gen_time);
        clock += gen_time;
        let cost = &train_cost;
        // reshard back
        let t = cost.reshard_time(n) * 0.3;
        timeline.record("cluster", &format!("{it}:reshard"), clock,
                        clock + t);
        clock += t;
        // reference + update over the global batch
        for mb in &mbs {
            let t = cost.ref_time(n, mb.len(), seq)
                + cost.train_time(n, mb.len(), seq);
            timeline.record("cluster", &format!("{it}:train"), clock,
                            clock + t);
            clock += t;
        }
        // DP gradient all-reduce + optimizer step over the full cluster.
        let t = cost.optimizer_sync_time(n);
        timeline.record("cluster", &format!("{it}:opt"), clock, clock + t);
        clock += t;
    }
    let utilization = timeline.utilization("cluster", clock);
    SimResult {
        mode: cfg.mode,
        devices: cfg.devices,
        makespan_s: clock,
        samples,
        tokens,
        timeline,
        utilization,
    }
}

// ---------------------------------------------------------------------------
// Task-separated modes
// ---------------------------------------------------------------------------

fn simulate_separated(cfg: &SimConfig, cost: &CostModel) -> SimResult {
    let timeline = Timeline::new();
    let mut rng = Rng::new(cfg.seed);
    let n_r = cfg.n_rollout_instances();
    let n_t = cfg.n_train_instances();
    let dev_r = cfg.rollout_instance_devices;
    let dev_t = cfg.train_instance_devices;
    let seq = cfg.workload.prompt_len + cfg.workload.median_response;
    let dynamic_pull = cfg.mode != Mode::SeparatedSequential;

    // Weight-sync cost at the iteration boundary.
    let sync_exposed = match cfg.mode {
        // blocking broadcast over collective links
        Mode::SeparatedSequential | Mode::SeparatedStreaming => {
            cost.weight_sync_time(cfg.train_devices(), cfg.rollout_devices())
        }
        // async path: only the H2D swap is exposed (delayed update)
        Mode::SeparatedAsync | Mode::SeparatedSubStep => {
            cost.weight_async_times().1
        }
        Mode::Colocated => unreachable!(),
    };

    let mut roll_free = vec![0.0f64; n_r];
    let mut train_free = vec![0.0f64; n_t];
    let mut samples = 0usize;
    let mut tokens = 0usize;
    // Completion bookkeeping for iteration gating.
    let mut rollout_all_done = vec![0.0f64; cfg.iterations];
    let mut iter_done = vec![0.0f64; cfg.iterations];

    for iter in 0..cfg.iterations {
        let mbs = generate_iteration(
            &cfg.workload,
            cfg.global_batch,
            cfg.micro_batch,
            &mut rng,
        );
        // When may rollout for this iteration start? (staleness gate)
        let release = match cfg.mode {
            Mode::SeparatedSequential | Mode::SeparatedStreaming => {
                // on-policy: after the previous update + weight sync
                if iter == 0 {
                    0.0
                } else {
                    iter_done[iter - 1] + sync_exposed
                }
            }
            Mode::SeparatedAsync => {
                // one-step staleness: iteration j may roll out once
                // iteration j-2 has trained (gate: j <= done + 1) and the
                // previous rollout finished; swap cost is the exposed H2D.
                let gate = if iter >= 2 { iter_done[iter - 2] } else { 0.0 };
                let prev_roll =
                    if iter >= 1 { rollout_all_done[iter - 1] } else { 0.0 };
                gate.max(prev_roll)
                    + if iter > 0 { sync_exposed } else { 0.0 }
            }
            Mode::SeparatedSubStep => {
                // Sub-step asynchrony: no global rollout barrier at all —
                // each instance swaps individually (handled below), so
                // iteration j is release-gated only by training progress.
                if iter >= 2 { iter_done[iter - 2] } else { 0.0 }
            }
            Mode::Colocated => unreachable!(),
        };

        // --- rollout phase -------------------------------------------------
        let mut mb_ready = Vec::with_capacity(mbs.len());
        for (k, mb) in mbs.iter().enumerate() {
            let inst = if dynamic_pull {
                // TransferQueue pull model: earliest-free instance.
                (0..n_r)
                    .min_by(|&a, &b| {
                        roll_free[a].partial_cmp(&roll_free[b]).unwrap()
                    })
                    .unwrap()
            } else {
                // static pre-assignment (no TQ): round-robin.
                k % n_r
            };
            let mut start = roll_free[inst].max(release);
            // Sub-step mode: the first micro-batch an instance takes in a
            // new iteration pays its own (staggered) swap; other modes pay
            // the swap inside `release`.
            if cfg.mode == Mode::SeparatedSubStep
                && iter > 0
                && roll_free[inst] <= release
            {
                start += sync_exposed;
            }
            let dur = cost.rollout_time(
                dev_r,
                mb.len(),
                cfg.workload.prompt_len,
                mb.max_response(),
            );
            let end = start + dur;
            timeline.record(
                &format!("rollout-{inst}"),
                &format!("i{iter}:gen"),
                start,
                end,
            );
            roll_free[inst] = end;
            mb_ready.push(end);
            samples += mb.len();
            tokens += mb.total_tokens();
        }
        let all_rolled =
            mb_ready.iter().copied().fold(0.0f64, f64::max);
        rollout_all_done[iter] = all_rolled;

        // --- train path (reference + update) ------------------------------
        let mut done_max = 0.0f64;
        for (k, mb) in mbs.iter().enumerate() {
            // Sequential mode: the train cluster may only start after the
            // whole global batch is rolled out (no streaming).
            let ready = if cfg.mode == Mode::SeparatedSequential {
                all_rolled
            } else {
                mb_ready[k]
            };
            let inst = (0..n_t)
                .min_by(|&a, &b| {
                    train_free[a].partial_cmp(&train_free[b]).unwrap()
                })
                .unwrap();
            let start = train_free[inst].max(ready);
            let t_ref = cost.ref_time(dev_t, mb.len(), seq);
            let t_upd = cost.train_time(dev_t, mb.len(), seq);
            timeline.record(
                &format!("train-{inst}"),
                &format!("i{iter}:ref"),
                start,
                start + t_ref,
            );
            timeline.record(
                &format!("train-{inst}"),
                &format!("i{iter}:upd"),
                start + t_ref,
                start + t_ref + t_upd,
            );
            train_free[inst] = start + t_ref + t_upd;
            done_max = done_max.max(train_free[inst]);
        }
        // Optimizer boundary: DP all-reduce across the train cluster.
        let opt = cost.optimizer_sync_time(cfg.train_devices());
        if opt > 0.0 {
            timeline.record(
                "train-0",
                &format!("i{iter}:opt"),
                done_max,
                done_max + opt,
            );
        }
        let done_max = done_max + opt;
        iter_done[iter] = done_max;
        if cfg.mode != Mode::SeparatedAsync {
            timeline.record(
                "weights",
                &format!("i{iter}:sync"),
                done_max,
                done_max + sync_exposed,
            );
        }
    }

    let makespan = timeline.horizon();
    let mut util_sum = 0.0;
    let mut util_n = 0;
    for w in timeline.workers() {
        if w.starts_with("rollout-") || w.starts_with("train-") {
            util_sum += timeline.utilization(&w, makespan);
            util_n += 1;
        }
    }
    SimResult {
        mode: cfg.mode,
        devices: cfg.devices,
        makespan_s: makespan,
        samples,
        tokens,
        timeline,
        utilization: if util_n > 0 { util_sum / util_n as f64 } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::cost_model::{DeviceSpec, LlmSpec};

    fn cost() -> CostModel {
        CostModel::new(DeviceSpec::ascend_910b(), LlmSpec::qwen_7b())
    }

    fn run(devices: usize, mode: Mode) -> SimResult {
        let mut cfg = SimConfig::defaults(devices, mode);
        cfg.iterations = 6;
        simulate(&cfg, &cost())
    }

    #[test]
    fn all_modes_complete_all_samples() {
        for mode in [
            Mode::Colocated,
            Mode::SeparatedSequential,
            Mode::SeparatedStreaming,
            Mode::SeparatedAsync,
            Mode::SeparatedSubStep,
        ] {
            let r = run(64, mode);
            assert_eq!(r.samples, 6 * SimConfig::defaults(64, mode).global_batch);
            assert!(r.makespan_s > 0.0);
            assert!(r.utilization > 0.0 && r.utilization <= 1.0);
        }
    }

    #[test]
    fn table1_ordering_holds() {
        // Table 1: baseline < +TransferQueue < +Async.
        let base = run(512, Mode::SeparatedSequential);
        let tq = run(512, Mode::SeparatedStreaming);
        let asy = run(512, Mode::SeparatedAsync);
        let t0 = base.throughput_samples_per_s();
        let t1 = tq.throughput_samples_per_s();
        let t2 = asy.throughput_samples_per_s();
        assert!(t1 > t0 * 1.2, "TQ streaming must beat sequential: {t1} vs {t0}");
        assert!(t2 > t1 * 1.05, "async must beat sync streaming: {t2} vs {t1}");
    }

    #[test]
    fn asyncflow_beats_colocated_at_scale() {
        let verl = run(256, Mode::Colocated);
        let af = run(256, Mode::SeparatedAsync);
        assert!(
            af.throughput_samples_per_s() > verl.throughput_samples_per_s(),
            "AsyncFlow {} <= verl {}",
            af.throughput_samples_per_s(),
            verl.throughput_samples_per_s()
        );
    }

    #[test]
    fn async_reduces_bubbles_vs_sequential() {
        let seq = run(128, Mode::SeparatedSequential);
        let asy = run(128, Mode::SeparatedAsync);
        assert!(asy.bubble_fraction() < seq.bubble_fraction());
    }

    #[test]
    fn substep_not_slower_than_async() {
        // Fig. 8(d): removing the global swap barrier can only help.
        let asy = run(256, Mode::SeparatedAsync);
        let sub = run(256, Mode::SeparatedSubStep);
        assert!(
            sub.throughput_samples_per_s()
                >= asy.throughput_samples_per_s() * 0.999,
            "substep {} < async {}",
            sub.throughput_samples_per_s(),
            asy.throughput_samples_per_s()
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(64, Mode::SeparatedAsync);
        let b = run(64, Mode::SeparatedAsync);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn gantt_has_rollout_and_train_rows() {
        let r = run(64, Mode::SeparatedAsync);
        let workers = r.timeline.workers();
        assert!(workers.iter().any(|w| w.starts_with("rollout-")));
        assert!(workers.iter().any(|w| w.starts_with("train-")));
    }
}
