//! Metrics: counters, histograms, timers, time-series, CSV/JSON emit.
//!
//! The trainer, TransferQueue, and benches all log through a [`Registry`];
//! series are exported for EXPERIMENTS.md plots (reward curves, Gantt
//! rows, throughput tables). [`Histogram`]s aggregate per-sample
//! distributions (staleness, queue age, time-to-first-sample) into
//! fixed log-scale buckets with p50/p95/p99 summaries for the
//! telemetry plane.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;

/// Default per-series point cap ([`Series::push`] decimates beyond
/// it). Large enough that benches and tests never hit it; small
/// enough that a week-long serve holds ~1MB per series, not all of
/// history.
pub const SERIES_CAP: usize = 65536;

/// A named time-series of (x, value) points with bounded memory.
///
/// Until [`SERIES_CAP`] points accumulate, every push is stored. At
/// the cap the series halves itself (keeping every 2nd point) and
/// doubles its keep-stride, so a long-running process stores an
/// evenly spaced subsample of its full history — deterministic,
/// order-preserving, ≤ `cap` points forever.
#[derive(Debug, Clone)]
pub struct Series {
    pub points: Vec<(f64, f64)>,
    cap: usize,
    stride: u64,
    pending: u64,
}

impl Default for Series {
    fn default() -> Self {
        Series::with_cap(SERIES_CAP)
    }
}

impl Series {
    /// An empty series storing at most `cap` points.
    pub fn with_cap(cap: usize) -> Self {
        Series {
            points: Vec::new(),
            cap: cap.max(2),
            stride: 1,
            pending: 0,
        }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.pending += 1;
        if self.pending % self.stride != 0 {
            return;
        }
        if self.points.len() >= self.cap {
            let mut i = 0usize;
            self.points.retain(|_| {
                let keep = i % 2 == 0;
                i += 1;
                keep
            });
            self.stride *= 2;
        }
        self.points.push((x, y));
    }

    /// Total values ever pushed (stored or decimated away).
    pub fn pushed(&self) -> u64 {
        self.pending
    }

    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|p| p.1)
    }

    pub fn mean(&self) -> f64 {
        if self.points.is_empty() {
            return f64::NAN;
        }
        self.points.iter().map(|p| p.1).sum::<f64>()
            / self.points.len() as f64
    }

    /// Mean of the tail fraction (e.g. last 25% — steady-state metrics).
    pub fn tail_mean(&self, frac: f64) -> f64 {
        if self.points.is_empty() {
            return f64::NAN;
        }
        let skip = ((1.0 - frac) * self.points.len() as f64) as usize;
        let tail = &self.points[skip.min(self.points.len() - 1)..];
        tail.iter().map(|p| p.1).sum::<f64>() / tail.len() as f64
    }
}

/// Number of log-scale buckets per [`Histogram`].
const HIST_BUCKETS: usize = 96;
/// Doublings below 1.0 covered by bucket 1 (bucket 0 holds ≤ 0).
const HIST_LOW_DOUBLINGS: f64 = 12.0;
/// Buckets per doubling (2 ⇒ ~41% bucket width).
const HIST_PER_DOUBLING: f64 = 2.0;

/// Point-in-time summary of a [`Histogram`] — the wire/display form.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: f64,
    /// Exact observed extremes (not bucket bounds).
    pub min: f64,
    pub max: f64,
    /// Estimated percentiles (log-bucket interpolation, clamped to
    /// the exact min/max).
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl HistSnapshot {
    /// Mean of all observations (`NaN` when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.sum / self.count as f64
    }
}

/// Fixed log-scale-bucket histogram: O(1) observe, constant memory,
/// percentile estimates within one bucket width (~41%) plus exact
/// min/max/sum/count. Covers 2^-12 (~0.00024) to 2^36 (~6.9e10) —
/// milliseconds to days when observing times, and any plausible
/// version-staleness count; values ≤ 0 land in bucket 0.
#[derive(Debug, Clone)]
pub struct Histogram {
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    buckets: Vec<u64>,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            count: 0,
            sum: 0.0,
            min: 0.0,
            max: 0.0,
            buckets: vec![0; HIST_BUCKETS],
        }
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    fn bucket_of(v: f64) -> usize {
        if v <= 0.0 || !v.is_finite() {
            return 0;
        }
        let idx = ((v.log2() + HIST_LOW_DOUBLINGS) * HIST_PER_DOUBLING)
            .floor();
        (idx.max(0.0) as usize + 1).min(HIST_BUCKETS - 1)
    }

    /// Lower bound of bucket `i` (for interpolation).
    fn bucket_lo(i: usize) -> f64 {
        ((i as f64 - 1.0) / HIST_PER_DOUBLING - HIST_LOW_DOUBLINGS)
            .exp2()
    }

    /// Record one observation.
    pub fn observe(&mut self, v: f64) {
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.count += 1;
        self.sum += v;
        self.buckets[Self::bucket_of(v)] += 1;
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Estimated percentile (`q` in [0,1]): find the bucket holding
    /// the rank, interpolate geometrically within it, clamp to the
    /// exact extremes. `NaN` when empty.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let rank = q.clamp(0.0, 1.0) * (self.count - 1) as f64;
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            if n == 0 {
                continue;
            }
            let hi_rank = (seen + n) as f64 - 1.0;
            if rank <= hi_rank {
                if i == 0 {
                    return self.min.min(0.0);
                }
                let frac = if n == 1 {
                    0.5
                } else {
                    (rank - seen as f64) / (n - 1) as f64
                };
                let lo = Self::bucket_lo(i);
                let est = lo * (frac / HIST_PER_DOUBLING).exp2();
                return est.clamp(self.min, self.max);
            }
            seen += n;
        }
        self.max
    }

    /// Summarize for export/display.
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            count: self.count,
            sum: self.sum,
            min: if self.count == 0 { 0.0 } else { self.min },
            max: if self.count == 0 { 0.0 } else { self.max },
            p50: self.percentile(0.50),
            p95: self.percentile(0.95),
            p99: self.percentile(0.99),
        }
    }
}

#[derive(Default)]
struct RegistryInner {
    counters: BTreeMap<String, u64>,
    series: BTreeMap<String, Series>,
    hists: BTreeMap<String, Histogram>,
}

/// Thread-safe metrics registry.
#[derive(Default)]
pub struct Registry {
    inner: Mutex<RegistryInner>,
    start: Option<Instant>,
}

impl Registry {
    pub fn new() -> Self {
        Registry { inner: Mutex::default(), start: Some(Instant::now()) }
    }

    /// Seconds since registry creation (x-axis for wall-clock series).
    pub fn elapsed(&self) -> f64 {
        self.start.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0)
    }

    pub fn inc(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .counters
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    pub fn record(&self, name: &str, x: f64, y: f64) {
        let mut g = self.inner.lock().unwrap();
        g.series.entry(name.to_string()).or_default().push(x, y);
    }

    /// Record against wall-clock x-axis.
    pub fn record_now(&self, name: &str, y: f64) {
        self.record(name, self.elapsed(), y);
    }

    pub fn series(&self, name: &str) -> Option<Series> {
        self.inner.lock().unwrap().series.get(name).cloned()
    }

    pub fn series_names(&self) -> Vec<String> {
        self.inner.lock().unwrap().series.keys().cloned().collect()
    }

    /// Record one observation into the named histogram.
    pub fn observe(&self, name: &str, v: f64) {
        let mut g = self.inner.lock().unwrap();
        g.hists.entry(name.to_string()).or_default().observe(v);
    }

    /// Summary of the named histogram (`None` if never observed).
    pub fn hist(&self, name: &str) -> Option<HistSnapshot> {
        self.inner
            .lock()
            .unwrap()
            .hists
            .get(name)
            .map(Histogram::snapshot)
    }

    /// Every histogram's summary, sorted by name.
    pub fn hist_snapshots(&self) -> Vec<(String, HistSnapshot)> {
        self.inner
            .lock()
            .unwrap()
            .hists
            .iter()
            .map(|(k, h)| (k.clone(), h.snapshot()))
            .collect()
    }

    /// Every counter's current value, sorted by name.
    pub fn counter_snapshots(&self) -> Vec<(String, u64)> {
        self.inner
            .lock()
            .unwrap()
            .counters
            .iter()
            .map(|(k, v)| (k.clone(), *v))
            .collect()
    }

    /// Export everything as JSON (for EXPERIMENTS.md artifacts).
    pub fn to_json(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let counters = Json::Obj(
            g.counters
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
                .collect(),
        );
        let series = Json::Obj(
            g.series
                .iter()
                .map(|(k, s)| {
                    (
                        k.clone(),
                        Json::Arr(
                            s.points
                                .iter()
                                .map(|(x, y)| {
                                    Json::Arr(vec![
                                        Json::Num(*x),
                                        Json::Num(*y),
                                    ])
                                })
                                .collect(),
                        ),
                    )
                })
                .collect(),
        );
        let hists = Json::Obj(
            g.hists
                .iter()
                .map(|(k, h)| {
                    let s = h.snapshot();
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("count", Json::Num(s.count as f64)),
                            ("sum", Json::Num(s.sum)),
                            ("min", Json::Num(s.min)),
                            ("max", Json::Num(s.max)),
                            ("p50", Json::Num(s.p50)),
                            ("p95", Json::Num(s.p95)),
                            ("p99", Json::Num(s.p99)),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("counters", counters),
            ("series", series),
            ("hists", hists),
        ])
    }

    /// Export one series as CSV text.
    pub fn series_csv(&self, name: &str) -> String {
        let mut out = String::from("x,y\n");
        if let Some(s) = self.series(name) {
            for (x, y) in s.points {
                out.push_str(&format!("{x},{y}\n"));
            }
        }
        out
    }
}

/// RAII timer recording elapsed seconds into a series on drop.
pub struct Timer<'a> {
    registry: &'a Registry,
    name: String,
    start: Instant,
}

impl<'a> Timer<'a> {
    pub fn start(registry: &'a Registry, name: impl Into<String>) -> Self {
        Timer { registry, name: name.into(), start: Instant::now() }
    }
}

impl Drop for Timer<'_> {
    fn drop(&mut self) {
        self.registry
            .record_now(&self.name, self.start.elapsed().as_secs_f64());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let r = Registry::new();
        r.inc("a", 2);
        r.inc("a", 3);
        assert_eq!(r.counter("a"), 5);
        assert_eq!(r.counter("missing"), 0);
    }

    #[test]
    fn series_record_and_stats() {
        let r = Registry::new();
        for i in 0..10 {
            r.record("loss", i as f64, 10.0 - i as f64);
        }
        let s = r.series("loss").unwrap();
        assert_eq!(s.points.len(), 10);
        assert_eq!(s.last(), Some(1.0));
        assert!((s.mean() - 5.5).abs() < 1e-12);
        // tail 20% = last 2 points: (8,2),(9,1) -> mean 1.5
        assert!((s.tail_mean(0.2) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn json_export_roundtrips() {
        let r = Registry::new();
        r.inc("n", 1);
        r.record("s", 0.0, 1.0);
        let j = r.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(
            parsed.path(&["counters", "n"]).unwrap().as_i64(),
            Some(1)
        );
    }

    #[test]
    fn csv_export() {
        let r = Registry::new();
        r.record("s", 1.0, 2.0);
        assert_eq!(r.series_csv("s"), "x,y\n1,2\n");
        assert_eq!(r.series_csv("none"), "x,y\n");
    }

    #[test]
    fn timer_records_on_drop() {
        let r = Registry::new();
        {
            let _t = Timer::start(&r, "op");
        }
        assert_eq!(r.series("op").unwrap().points.len(), 1);
    }

    #[test]
    fn series_cap_decimates_instead_of_growing() {
        let mut s = Series::with_cap(8);
        for i in 0..1000 {
            s.push(i as f64, (i * 2) as f64);
        }
        assert!(s.points.len() <= 8, "bounded: {}", s.points.len());
        assert_eq!(s.pushed(), 1000);
        // Order and pairing survive decimation.
        for w in s.points.windows(2) {
            assert!(w[0].0 < w[1].0, "x stays sorted");
        }
        for (x, y) in &s.points {
            assert_eq!(*y, x * 2.0, "points never mix");
        }
        // Coverage spans the whole history, not just a prefix.
        let last_x = s.points.last().unwrap().0;
        assert!(last_x >= 500.0, "tail retained: {last_x}");
        // Stats still work on the subsample.
        assert!(s.mean().is_finite());
        assert!(s.last().is_some());
    }

    #[test]
    fn series_below_cap_stores_everything() {
        let mut s = Series::with_cap(100);
        for i in 0..100 {
            s.push(i as f64, 0.0);
        }
        assert_eq!(s.points.len(), 100, "no decimation below the cap");
    }

    #[test]
    fn histogram_percentiles_bracket_the_data() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.observe(i as f64);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 1000);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 1000.0);
        assert!((s.mean() - 500.5).abs() < 1e-9);
        // Log buckets are ~41% wide: accept that tolerance.
        assert!(
            s.p50 > 250.0 && s.p50 < 1000.0,
            "p50 in range: {}",
            s.p50
        );
        assert!(s.p95 >= s.p50 && s.p99 >= s.p95, "monotone");
        assert!(s.p99 <= 1000.0, "clamped to max");
    }

    #[test]
    fn histogram_handles_empty_zero_and_single() {
        let h = Histogram::new();
        assert!(h.percentile(0.5).is_nan());
        assert_eq!(h.snapshot().count, 0);
        let mut h = Histogram::new();
        h.observe(0.0);
        h.observe(-3.0);
        let s = h.snapshot();
        assert_eq!(s.min, -3.0);
        assert!(s.p50 <= 0.0, "non-positive bucket: {}", s.p50);
        let mut h = Histogram::new();
        h.observe(42.0);
        let s = h.snapshot();
        assert_eq!((s.min, s.max), (42.0, 42.0));
        assert_eq!(s.p50, 42.0, "single value is every percentile");
    }

    #[test]
    fn registry_histograms_and_snapshots() {
        let r = Registry::new();
        for i in 0..100 {
            r.observe("staleness", i as f64);
        }
        r.inc("n", 7);
        let s = r.hist("staleness").unwrap();
        assert_eq!(s.count, 100);
        assert!(r.hist("missing").is_none());
        let hists = r.hist_snapshots();
        assert_eq!(hists.len(), 1);
        assert_eq!(hists[0].0, "staleness");
        assert_eq!(r.counter_snapshots(), vec![("n".to_string(), 7)]);
        // Histograms ride the JSON export.
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(
            j.path(&["hists", "staleness", "count"])
                .unwrap()
                .as_i64(),
            Some(100)
        );
    }
}
