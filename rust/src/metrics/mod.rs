//! Metrics: counters, timers, time-series recording, CSV/JSON emit.
//!
//! The trainer, TransferQueue, and benches all log through a [`Registry`];
//! series are exported for EXPERIMENTS.md plots (reward curves, Gantt
//! rows, throughput tables).

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;

/// A named time-series of (x, value) points.
#[derive(Debug, Clone, Default)]
pub struct Series {
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|p| p.1)
    }

    pub fn mean(&self) -> f64 {
        if self.points.is_empty() {
            return f64::NAN;
        }
        self.points.iter().map(|p| p.1).sum::<f64>()
            / self.points.len() as f64
    }

    /// Mean of the tail fraction (e.g. last 25% — steady-state metrics).
    pub fn tail_mean(&self, frac: f64) -> f64 {
        if self.points.is_empty() {
            return f64::NAN;
        }
        let skip = ((1.0 - frac) * self.points.len() as f64) as usize;
        let tail = &self.points[skip.min(self.points.len() - 1)..];
        tail.iter().map(|p| p.1).sum::<f64>() / tail.len() as f64
    }
}

#[derive(Default)]
struct RegistryInner {
    counters: BTreeMap<String, u64>,
    series: BTreeMap<String, Series>,
}

/// Thread-safe metrics registry.
#[derive(Default)]
pub struct Registry {
    inner: Mutex<RegistryInner>,
    start: Option<Instant>,
}

impl Registry {
    pub fn new() -> Self {
        Registry { inner: Mutex::default(), start: Some(Instant::now()) }
    }

    /// Seconds since registry creation (x-axis for wall-clock series).
    pub fn elapsed(&self) -> f64 {
        self.start.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0)
    }

    pub fn inc(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .counters
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    pub fn record(&self, name: &str, x: f64, y: f64) {
        let mut g = self.inner.lock().unwrap();
        g.series.entry(name.to_string()).or_default().push(x, y);
    }

    /// Record against wall-clock x-axis.
    pub fn record_now(&self, name: &str, y: f64) {
        self.record(name, self.elapsed(), y);
    }

    pub fn series(&self, name: &str) -> Option<Series> {
        self.inner.lock().unwrap().series.get(name).cloned()
    }

    pub fn series_names(&self) -> Vec<String> {
        self.inner.lock().unwrap().series.keys().cloned().collect()
    }

    /// Export everything as JSON (for EXPERIMENTS.md artifacts).
    pub fn to_json(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let counters = Json::Obj(
            g.counters
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
                .collect(),
        );
        let series = Json::Obj(
            g.series
                .iter()
                .map(|(k, s)| {
                    (
                        k.clone(),
                        Json::Arr(
                            s.points
                                .iter()
                                .map(|(x, y)| {
                                    Json::Arr(vec![
                                        Json::Num(*x),
                                        Json::Num(*y),
                                    ])
                                })
                                .collect(),
                        ),
                    )
                })
                .collect(),
        );
        Json::obj(vec![("counters", counters), ("series", series)])
    }

    /// Export one series as CSV text.
    pub fn series_csv(&self, name: &str) -> String {
        let mut out = String::from("x,y\n");
        if let Some(s) = self.series(name) {
            for (x, y) in s.points {
                out.push_str(&format!("{x},{y}\n"));
            }
        }
        out
    }
}

/// RAII timer recording elapsed seconds into a series on drop.
pub struct Timer<'a> {
    registry: &'a Registry,
    name: String,
    start: Instant,
}

impl<'a> Timer<'a> {
    pub fn start(registry: &'a Registry, name: impl Into<String>) -> Self {
        Timer { registry, name: name.into(), start: Instant::now() }
    }
}

impl Drop for Timer<'_> {
    fn drop(&mut self) {
        self.registry
            .record_now(&self.name, self.start.elapsed().as_secs_f64());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let r = Registry::new();
        r.inc("a", 2);
        r.inc("a", 3);
        assert_eq!(r.counter("a"), 5);
        assert_eq!(r.counter("missing"), 0);
    }

    #[test]
    fn series_record_and_stats() {
        let r = Registry::new();
        for i in 0..10 {
            r.record("loss", i as f64, 10.0 - i as f64);
        }
        let s = r.series("loss").unwrap();
        assert_eq!(s.points.len(), 10);
        assert_eq!(s.last(), Some(1.0));
        assert!((s.mean() - 5.5).abs() < 1e-12);
        // tail 20% = last 2 points: (8,2),(9,1) -> mean 1.5
        assert!((s.tail_mean(0.2) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn json_export_roundtrips() {
        let r = Registry::new();
        r.inc("n", 1);
        r.record("s", 0.0, 1.0);
        let j = r.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(
            parsed.path(&["counters", "n"]).unwrap().as_i64(),
            Some(1)
        );
    }

    #[test]
    fn csv_export() {
        let r = Registry::new();
        r.record("s", 1.0, 2.0);
        assert_eq!(r.series_csv("s"), "x,y\n1,2\n");
        assert_eq!(r.series_csv("none"), "x,y\n");
    }

    #[test]
    fn timer_records_on_drop() {
        let r = Registry::new();
        {
            let _t = Timer::start(&r, "op");
        }
        assert_eq!(r.series("op").unwrap().points.len(), 1);
    }
}
