//! Heterogeneous engine fleet: a capability-modeled backend registry
//! plus routing policies over lease dispatch.
//!
//! AsyncFlow's rollout layer can scale workers, but a single statically
//! chosen `PolicyEngine` backend per run leaves two gaps: mixed fleets
//! (fast/cheap engines next to slow/accurate ones) and long-tail
//! generations serializing behind whichever engine got unlucky. This
//! module closes both:
//!
//! * [`EngineSpec`] models what an engine *is* — kind, compiled
//!   geometry, speed class, tags — so the coordinator can reason about
//!   which engines can stand in for which ([`EngineSpec::can_stand_in_for`]).
//!   Specs register statically (config) or dynamically at worker attach
//!   (the spec rides `lease_prompts` / `worker_stats`).
//! * [`FleetRouter`] implements the routing policies
//!   ([`RoutingPolicy`]): **load-balance** (least-outstanding capable
//!   candidate), **fallback** (engine errors requeue the lease
//!   immediately via `fail_lease` instead of waiting out the TTL),
//!   **hedge** (duplicate a straggler's remaining rows to a second
//!   engine once its silence exceeds a budget derived from the fleet's
//!   observed chunk-time distribution; first finisher commits, the
//!   loser's rows are revoked through the lease table so exactly-once
//!   conservation holds), and **mirror** (duplicate to N engines and
//!   compare outputs — the engine-correctness soak test).
//!
//! See DESIGN.md §Engine fleet for the state machines and the
//! hedge-revocation sequence.

pub mod router;
pub mod spec;

pub use router::{
    DupMode, EngineStat, FleetOptions, FleetRouter, FleetStats, RowPlan,
};
pub use spec::{EngineSpec, RoutingPolicy, SpeedClass};
