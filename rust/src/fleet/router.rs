//! The fleet router: routing-policy state machine over lease dispatch.
//!
//! The router never owns leases or rows — `LeaseTable`/`LeaseRegistry`
//! stay the single source of truth for exactly-once conservation. The
//! router is a bookkeeping layer the `RolloutManager` consults at three
//! points:
//!
//! * **poll time** (`lease_prompts`): defer a loaded worker's poll
//!   (load-balance), or grant a straggler's remaining rows to a second
//!   engine (hedge) / a fresh duplicate (mirror) when no queued rows are
//!   ready.
//! * **commit time** (`put_chunk`): [`FleetRouter::filter_chunk`]
//!   atomically decides, per row, whether this lease commits the row,
//!   drops it (a hedge loser), or compares it (a mirror duplicate) —
//!   the winner of a duplicated row is chosen under the router lock, so
//!   two engines racing the same row can never both commit.
//! * **death time** (`fail_lease`, TTL sweep): decide which of a dead
//!   lease's rows actually requeue — a row whose duplicate is still
//!   live (or already committed) must not requeue, and a row whose
//!   *both* copies died in one sweep must requeue exactly once.

use std::collections::{HashMap, HashSet};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::transfer_queue::{GlobalIndex, LeaseId, RevokedLease};

use super::spec::{EngineSpec, RoutingPolicy};

/// Tunables for the routing layer (the `[fleet]` config table).
#[derive(Debug, Clone, PartialEq)]
pub struct FleetOptions {
    /// Active routing policy.
    pub policy: RoutingPolicy,
    /// Hedge latency budget = `max(hedge_min_ms, hedge_factor × p95)`
    /// of the observed chunk-interval distribution.
    pub hedge_factor: f64,
    /// Floor of the hedge budget in milliseconds.
    pub hedge_min_ms: u64,
    /// Minimum observed chunk intervals before hedging arms.
    pub hedge_min_samples: usize,
    /// Engines per row under mirror routing (the primary plus
    /// `mirror_fanout - 1` duplicates).
    pub mirror_fanout: usize,
    /// A peer counts as "actively polling" for load-balance deferral
    /// if it polled within this window (milliseconds).
    pub lb_window_ms: u64,
}

impl Default for FleetOptions {
    fn default() -> Self {
        FleetOptions {
            policy: RoutingPolicy::LoadBalance,
            hedge_factor: 3.0,
            hedge_min_ms: 25,
            hedge_min_samples: 8,
            mirror_fanout: 2,
            lb_window_ms: 1000,
        }
    }
}

/// How a duplicated row pair was created.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DupMode {
    /// Straggler rescue: the loser's copy is revoked, its tokens
    /// counted as duplicated decode work.
    Hedge,
    /// Correctness soak: the loser's copy is compared against the
    /// winner's committed tokens before being discarded.
    Mirror,
}

/// Per-row verdict from [`FleetRouter::filter_chunk`], parallel to the
/// input rows.
#[derive(Debug, Clone, PartialEq)]
pub enum RowPlan {
    /// Commit through the normal `append_rows` path. For a finished
    /// row that wins a duplicated pair, `losers` names the lease(s)
    /// whose copy of this row must be discarded now (hedge
    /// revocation).
    Commit {
        /// Leases whose copy of the row loses to this commit.
        losers: Vec<LeaseId>,
    },
    /// Hedge-loser row (the duplicate already committed): drop the
    /// chunk, discard the buffered copy if finished.
    Drop,
    /// Mirror-loser finished row: discard the buffered copy and hand
    /// the full token sequence to [`FleetRouter::resolve_mirror`].
    Compare,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Role {
    Primary,
    Hedge,
    Mirror,
}

struct LeaseMeta {
    worker: String,
    task: String,
    role: Role,
    /// Duplicate leases granted against this one (primary side only).
    partners: Vec<LeaseId>,
    /// Duplicates promised but not yet recorded: a candidate pick
    /// reserves the primary under the router lock so two concurrent
    /// idle pollers can never both hedge (or over-fan a mirror of)
    /// the same straggler. `record_dup` consumes the reservation;
    /// a failed grant releases it.
    reserved_dups: usize,
    last_activity: Instant,
}

struct EngineEntry {
    spec: EngineSpec,
    spec_reported: bool,
    source: &'static str,
    last_poll: Option<Instant>,
    first_chunk: Option<Instant>,
    last_chunk: Option<Instant>,
    chunks: u64,
    tokens: u64,
    errors: u64,
    hedge_rows_won: u64,
    hedge_rows_lost: u64,
}

impl EngineEntry {
    fn placeholder() -> EngineEntry {
        EngineEntry {
            spec: EngineSpec::new("unreported", 0, 0, 0),
            spec_reported: false,
            source: "attach",
            last_poll: None,
            first_chunk: None,
            last_chunk: None,
            chunks: 0,
            tokens: 0,
            errors: 0,
            hedge_rows_won: 0,
            hedge_rows_lost: 0,
        }
    }

    fn observed_tps(&self) -> f64 {
        match (self.first_chunk, self.last_chunk) {
            (Some(a), Some(b)) if b > a => {
                self.tokens as f64 / (b - a).as_secs_f64()
            }
            _ => self.spec.observed_tps,
        }
    }
}

/// One duplicated row: the leases racing it and, once decided, the
/// winner. `winner_tokens` / `pending` exist so a mirror comparison
/// can resolve regardless of which side's `put_chunk` lands first.
struct DupEntry {
    mode: DupMode,
    participants: Vec<LeaseId>,
    winner: Option<LeaseId>,
    winner_tokens: Option<Vec<i32>>,
    pending: Vec<Vec<i32>>,
    /// The row's cells were committed outside the duplicated pair
    /// (the primary raced `record_dup` and committed as a plain row,
    /// or the row was revoked, requeued, and re-leased elsewhere).
    /// Every participant's chunks divert, and no participant's death
    /// requeues the row.
    foreign_commit: bool,
}

#[derive(Default)]
struct Counters {
    hedges_issued: u64,
    hedge_rows_won_by_duplicate: u64,
    hedge_rows_won_by_primary: u64,
    duplicated_tokens: u64,
    mirrors_issued: u64,
    mirror_matches: u64,
    mirror_divergences: u64,
    lb_deferrals: u64,
    fallback_requeues: u64,
}

struct Inner {
    options: FleetOptions,
    engines: HashMap<String, EngineEntry>,
    leases: HashMap<LeaseId, LeaseMeta>,
    rows: HashMap<GlobalIndex, DupEntry>,
    /// Ring of observed chunk intervals (ms) across the fleet — the
    /// distribution the hedge budget is derived from.
    intervals: Vec<f64>,
    interval_at: usize,
    counters: Counters,
}

const INTERVAL_RING: usize = 512;

/// Per-engine slice of [`FleetStats`].
#[derive(Debug, Clone, PartialEq)]
pub struct EngineStat {
    pub worker: String,
    pub spec: EngineSpec,
    /// Whether a real capability report backs `spec` (old workers
    /// never send one; they show up as an unreported placeholder).
    pub spec_reported: bool,
    /// `"config"` or `"attach"`.
    pub source: String,
    pub chunks: u64,
    pub tokens: u64,
    pub errors: u64,
    pub hedge_rows_won: u64,
    pub hedge_rows_lost: u64,
    pub observed_tps: f64,
}

/// Snapshot of the routing layer (`stats.fleet`, rendered by
/// `asyncflow info --connect`).
#[derive(Debug, Clone, PartialEq)]
pub struct FleetStats {
    pub routing: String,
    pub engines: Vec<EngineStat>,
    pub chunk_time_p50_ms: f64,
    pub chunk_time_p95_ms: f64,
    /// Current hedge latency budget (0 until enough samples).
    pub hedge_budget_ms: f64,
    pub hedges_issued: u64,
    pub hedge_rows_won_by_duplicate: u64,
    pub hedge_rows_won_by_primary: u64,
    pub duplicated_tokens: u64,
    pub mirrors_issued: u64,
    pub mirror_matches: u64,
    pub mirror_divergences: u64,
    pub lb_deferrals: u64,
    pub fallback_requeues: u64,
}

/// What [`FleetRouter::filter_chunk`] decided for one row, before the
/// shared counters are updated.
enum Decision {
    Plain,
    Drop,
    Compare,
    Win { mode: DupMode, losers: Vec<LeaseId> },
}

/// Thread-safe fleet router. One per `RolloutManager`.
pub struct FleetRouter {
    inner: Mutex<Inner>,
}

impl Default for FleetRouter {
    fn default() -> Self {
        FleetRouter::new(FleetOptions::default())
    }
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = ((sorted.len() as f64 * q) as usize).min(sorted.len() - 1);
    sorted[pos]
}

impl FleetRouter {
    pub fn new(options: FleetOptions) -> FleetRouter {
        FleetRouter {
            inner: Mutex::new(Inner {
                options,
                engines: HashMap::new(),
                leases: HashMap::new(),
                rows: HashMap::new(),
                intervals: Vec::new(),
                interval_at: 0,
                counters: Counters::default(),
            }),
        }
    }

    /// Replace the routing options (a policy switch mid-run is allowed;
    /// existing duplicated rows keep resolving under their own mode).
    pub fn configure(&self, options: FleetOptions) {
        self.inner.lock().unwrap().options = options;
    }

    /// The active routing policy.
    pub fn policy(&self) -> RoutingPolicy {
        self.inner.lock().unwrap().options.policy
    }

    /// Register (or refresh) an engine's capability spec.
    pub fn register_engine(
        &self,
        worker: &str,
        spec: EngineSpec,
        source: &'static str,
    ) {
        let mut g = self.inner.lock().unwrap();
        let e = g
            .engines
            .entry(worker.to_string())
            .or_insert_with(EngineEntry::placeholder);
        e.spec = spec;
        e.spec_reported = true;
        e.source = source;
    }

    /// A worker polled `lease_prompts`, optionally carrying its engine
    /// spec (lenient: old workers send none and still participate).
    pub fn note_poll(&self, worker: &str, spec: Option<&EngineSpec>) {
        let mut g = self.inner.lock().unwrap();
        let e = g
            .engines
            .entry(worker.to_string())
            .or_insert_with(EngineEntry::placeholder);
        if let Some(s) = spec {
            if !e.spec_reported || e.spec != *s {
                e.spec = s.clone();
            }
            e.spec_reported = true;
        }
        e.last_poll = Some(Instant::now());
    }

    /// Load-balance deferral: should this worker's poll return empty
    /// even though rows are ready? Only when a strictly-less-loaded
    /// peer polled recently — the least-loaded active poller never
    /// defers, so dispatch always makes progress. Callers must only
    /// consult this when rows are actually queued: a deferral both
    /// counts in `lb_deferrals` and costs the worker its long-poll,
    /// neither of which is right when there was nothing to defer.
    pub fn should_defer(
        &self,
        worker: &str,
        load: &HashMap<String, (usize, usize)>,
    ) -> bool {
        let mut g = self.inner.lock().unwrap();
        if !matches!(
            g.options.policy,
            RoutingPolicy::LoadBalance | RoutingPolicy::Fallback
        ) {
            return false;
        }
        let mine = load.get(worker).copied().unwrap_or((0, 0));
        if mine.1 == 0 {
            return false;
        }
        let window = Duration::from_millis(g.options.lb_window_ms);
        let now = Instant::now();
        let defer = g.engines.iter().any(|(name, e)| {
            name.as_str() != worker
                && e.last_poll
                    .is_some_and(|t| now.duration_since(t) <= window)
                && load.get(name).copied().unwrap_or((0, 0)).1 < mine.1
        });
        if defer {
            g.counters.lb_deferrals += 1;
        }
        defer
    }

    /// A primary lease was granted.
    pub fn on_grant(&self, lease: LeaseId, worker: &str, task: &str) {
        let mut g = self.inner.lock().unwrap();
        g.leases.insert(
            lease,
            LeaseMeta {
                worker: worker.to_string(),
                task: task.to_string(),
                role: Role::Primary,
                partners: Vec::new(),
                reserved_dups: 0,
                last_activity: Instant::now(),
            },
        );
    }

    fn budget_ms(g: &Inner) -> Option<f64> {
        if g.intervals.len() < g.options.hedge_min_samples.max(1) {
            return None;
        }
        let mut sorted = g.intervals.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let p95 = percentile(&sorted, 0.95);
        Some(
            (g.options.hedge_factor * p95)
                .max(g.options.hedge_min_ms as f64),
        )
    }

    /// Hedge: pick the most-overdue straggler lease whose remaining
    /// rows `poller` should duplicate. Fires only once the fleet's
    /// chunk-interval distribution has enough samples, and only
    /// against a primary lease on a *different* worker with no
    /// duplicate yet whose silence exceeds the latency budget. The
    /// pick *reserves* the primary under this same lock (see
    /// [`LeaseMeta::reserved_dups`]); the caller must follow up with
    /// [`FleetRouter::record_dup`] or
    /// [`FleetRouter::release_duplicate`].
    pub fn hedge_candidate(
        &self,
        poller: &str,
        task: &str,
    ) -> Option<LeaseId> {
        let mut g = self.inner.lock().unwrap();
        if g.options.policy != RoutingPolicy::Hedge {
            return None;
        }
        let budget_ms = Self::budget_ms(&g)?;
        let poller_spec = match g.engines.get(poller) {
            Some(e) if e.spec_reported => Some(e.spec.clone()),
            _ => None,
        };
        let now = Instant::now();
        let mut best: Option<(f64, LeaseId)> = None;
        for (id, meta) in &g.leases {
            if meta.role != Role::Primary
                || !meta.partners.is_empty()
                || meta.reserved_dups > 0
                || meta.task != task
                || meta.worker == poller
            {
                continue;
            }
            let silent_ms =
                now.duration_since(meta.last_activity).as_secs_f64() * 1e3;
            if silent_ms <= budget_ms {
                continue;
            }
            if let Some(ps) = &poller_spec {
                if let Some(e) = g.engines.get(&meta.worker) {
                    if e.spec_reported && !ps.can_stand_in_for(&e.spec) {
                        continue;
                    }
                }
            }
            let better = match best {
                Some((s, _)) => silent_ms > s,
                None => true,
            };
            if better {
                best = Some((silent_ms, *id));
            }
        }
        let id = best.map(|(_, id)| id)?;
        if let Some(meta) = g.leases.get_mut(&id) {
            meta.reserved_dups += 1;
        }
        Some(id)
    }

    /// Mirror: pick a primary lease on a different worker that still
    /// has fewer than `mirror_fanout - 1` duplicates (reservations
    /// included — see [`FleetRouter::hedge_candidate`] for the
    /// reserve/consume/release contract).
    pub fn mirror_candidate(
        &self,
        poller: &str,
        task: &str,
    ) -> Option<LeaseId> {
        let mut g = self.inner.lock().unwrap();
        if g.options.policy != RoutingPolicy::Mirror {
            return None;
        }
        let want = g.options.mirror_fanout.saturating_sub(1).max(1);
        let poller_spec = match g.engines.get(poller) {
            Some(e) if e.spec_reported => Some(e.spec.clone()),
            _ => None,
        };
        let mut picked = None;
        for (id, meta) in &g.leases {
            if meta.role != Role::Primary
                || meta.partners.len() + meta.reserved_dups >= want
                || meta.task != task
                || meta.worker == poller
            {
                continue;
            }
            let poller_already_in = meta.partners.iter().any(|p| {
                g.leases.get(p).is_some_and(|m| m.worker == poller)
            });
            if poller_already_in {
                continue;
            }
            if let Some(ps) = &poller_spec {
                if let Some(e) = g.engines.get(&meta.worker) {
                    if e.spec_reported && !ps.can_stand_in_for(&e.spec) {
                        continue;
                    }
                }
            }
            picked = Some(*id);
            break;
        }
        let id = picked?;
        if let Some(meta) = g.leases.get_mut(&id) {
            meta.reserved_dups += 1;
        }
        Some(id)
    }

    /// Release a duplication reservation taken by
    /// [`FleetRouter::hedge_candidate`] /
    /// [`FleetRouter::mirror_candidate`] when the duplicate grant
    /// could not go through (no undone rows left, fetch failed, the
    /// primary died).
    pub fn release_duplicate(&self, primary: LeaseId) {
        let mut g = self.inner.lock().unwrap();
        if let Some(meta) = g.leases.get_mut(&primary) {
            meta.reserved_dups = meta.reserved_dups.saturating_sub(1);
        }
    }

    /// A duplicate lease `dup` was granted against `primary`, covering
    /// `rows` (the primary's rows still undone at hedge/mirror time).
    pub fn record_dup(
        &self,
        primary: LeaseId,
        dup: LeaseId,
        dup_worker: &str,
        task: &str,
        rows: &[GlobalIndex],
        mode: DupMode,
    ) {
        let mut g = self.inner.lock().unwrap();
        let role = match mode {
            DupMode::Hedge => Role::Hedge,
            DupMode::Mirror => Role::Mirror,
        };
        g.leases.insert(
            dup,
            LeaseMeta {
                worker: dup_worker.to_string(),
                task: task.to_string(),
                role,
                partners: vec![primary],
                reserved_dups: 0,
                last_activity: Instant::now(),
            },
        );
        if let Some(meta) = g.leases.get_mut(&primary) {
            meta.reserved_dups = meta.reserved_dups.saturating_sub(1);
            meta.partners.push(dup);
        }
        for idx in rows {
            let entry = g.rows.entry(*idx).or_insert_with(|| DupEntry {
                mode,
                participants: vec![primary],
                winner: None,
                winner_tokens: None,
                pending: Vec::new(),
                foreign_commit: false,
            });
            if !entry.participants.contains(&dup) {
                entry.participants.push(dup);
            }
        }
        match mode {
            DupMode::Hedge => g.counters.hedges_issued += 1,
            DupMode::Mirror => g.counters.mirrors_issued += 1,
        }
    }

    /// Atomic per-chunk routing decision (see module docs). `rows` is
    /// `(index, finished, chunk_tokens)` in chunk order; the returned
    /// plans are parallel to it. Also records the chunk interval into
    /// the hedge-budget distribution and the engine's counters.
    ///
    /// The second return value lists the duplicated rows this call
    /// *claimed* the win for. A claim is provisional: it is taken
    /// under the router lock (so the partner's racing chunk diverts)
    /// but the caller owes a [`FleetRouter::confirm_claim`] once the
    /// row's cells are durably committed — or a
    /// [`FleetRouter::rollback_claims`] if the commit fails, so the
    /// row stays winnable (and requeueable) instead of stranding
    /// behind a winner that never committed.
    pub fn filter_chunk(
        &self,
        lease: LeaseId,
        rows: &[(GlobalIndex, bool, usize)],
    ) -> (Vec<RowPlan>, Vec<GlobalIndex>) {
        let mut g = self.inner.lock().unwrap();
        let now = Instant::now();
        let chunk_tokens: usize = rows.iter().map(|r| r.2).sum();

        let mut activity: Option<(f64, String)> = None;
        if let Some(meta) = g.leases.get_mut(&lease) {
            let dt_ms =
                now.duration_since(meta.last_activity).as_secs_f64() * 1e3;
            meta.last_activity = now;
            activity = Some((dt_ms, meta.worker.clone()));
        }
        if let Some((dt_ms, worker)) = activity {
            if g.intervals.len() < INTERVAL_RING {
                g.intervals.push(dt_ms);
            } else {
                let at = g.interval_at % INTERVAL_RING;
                g.intervals[at] = dt_ms;
            }
            g.interval_at += 1;
            let e = g
                .engines
                .entry(worker)
                .or_insert_with(EngineEntry::placeholder);
            e.chunks += 1;
            e.tokens += chunk_tokens as u64;
            if e.first_chunk.is_none() {
                e.first_chunk = Some(now);
            }
            e.last_chunk = Some(now);
        }

        let mut plans = Vec::with_capacity(rows.len());
        let mut claimed = Vec::new();
        for (idx, finished, _) in rows {
            // Decide with the row entry borrowed; for a contested
            // finish, claim the win under this same lock so the other
            // side's racing chunk sees it and diverts. Accounting is
            // deferred to `confirm_claim` — a claim only becomes a win
            // once the caller's commit actually lands.
            let decision = match g.rows.get_mut(idx) {
                None => Decision::Plain,
                // A lease outside the duplicated pair never contends
                // for the row: routing returns Plain and the lease
                // table (which this lease does not own the row in)
                // rejects the chunk. Without this, any worker that
                // sent a stray index could steal the pair's win.
                Some(entry) if !entry.participants.contains(&lease) => {
                    Decision::Plain
                }
                // Committed outside the pair (a duplicate-grant race):
                // every participant's copy just diverts.
                Some(entry) if entry.foreign_commit => Decision::Drop,
                Some(entry) => match entry.winner {
                    Some(w) if w == lease => Decision::Drop,
                    Some(_) => match entry.mode {
                        DupMode::Hedge => Decision::Drop,
                        DupMode::Mirror if *finished => Decision::Compare,
                        DupMode::Mirror => Decision::Drop,
                    },
                    None if *finished => {
                        entry.winner = Some(lease);
                        claimed.push(*idx);
                        let losers: Vec<LeaseId> = entry
                            .participants
                            .iter()
                            .copied()
                            .filter(|p| *p != lease)
                            .collect();
                        Decision::Win { mode: entry.mode, losers }
                    }
                    None => Decision::Plain,
                },
            };
            match decision {
                Decision::Plain => {
                    plans.push(RowPlan::Commit { losers: Vec::new() });
                }
                Decision::Drop => plans.push(RowPlan::Drop),
                Decision::Compare => plans.push(RowPlan::Compare),
                Decision::Win { mode: DupMode::Mirror, .. } => {
                    // Mirror keeps the losers decoding so their
                    // finished rows can be compared.
                    plans.push(RowPlan::Commit { losers: Vec::new() });
                }
                Decision::Win { mode: DupMode::Hedge, losers } => {
                    plans.push(RowPlan::Commit { losers });
                }
            }
        }
        (plans, claimed)
    }

    /// A claimed row's cells committed durably: the claim is now a
    /// win — account it (hedge won/lost counters; mirror wins carry no
    /// counters of their own, comparison resolution does).
    pub fn confirm_claim(&self, lease: LeaseId, index: GlobalIndex) {
        let mut g = self.inner.lock().unwrap();
        let losers = {
            let Some(entry) = g.rows.get(&index) else { return };
            if entry.winner != Some(lease)
                || entry.mode != DupMode::Hedge
            {
                return;
            }
            entry
                .participants
                .iter()
                .copied()
                .filter(|p| *p != lease)
                .collect::<Vec<_>>()
        };
        let winner_role = g
            .leases
            .get(&lease)
            .map(|m| m.role)
            .unwrap_or(Role::Primary);
        let winner_worker = g.leases.get(&lease).map(|m| m.worker.clone());
        let loser_workers: Vec<String> = losers
            .iter()
            .filter_map(|l| g.leases.get(l).map(|m| m.worker.clone()))
            .collect();
        if winner_role == Role::Hedge {
            g.counters.hedge_rows_won_by_duplicate += 1;
        } else {
            g.counters.hedge_rows_won_by_primary += 1;
        }
        if let Some(w) = winner_worker {
            if let Some(e) = g.engines.get_mut(&w) {
                e.hedge_rows_won += 1;
            }
        }
        for w in loser_workers {
            if let Some(e) = g.engines.get_mut(&w) {
                e.hedge_rows_lost += 1;
            }
        }
    }

    /// Undo provisional winner claims taken by
    /// [`FleetRouter::filter_chunk`] whose commit never landed (the
    /// chunk was rejected downstream). The rows become winnable again
    /// — by either side — and a later sweep requeues them normally
    /// instead of treating them as committed.
    pub fn rollback_claims(&self, lease: LeaseId, rows: &[GlobalIndex]) {
        let mut g = self.inner.lock().unwrap();
        for idx in rows {
            if let Some(entry) = g.rows.get_mut(idx) {
                if entry.winner == Some(lease) {
                    entry.winner = None;
                }
            }
        }
    }

    /// A duplicated row turned out to be committed outside its pair
    /// (its cells exist but no participant won it): clear any
    /// provisional claim `lease` holds on it and mark the entry so
    /// every participant's chunks divert and no participant's death
    /// requeues it. Returns `false` when the row is not duplicated —
    /// the caller then treats the squatted cell as the protocol
    /// violation it is.
    pub fn note_foreign_commit(
        &self,
        lease: LeaseId,
        index: GlobalIndex,
    ) -> bool {
        let mut g = self.inner.lock().unwrap();
        let Some(entry) = g.rows.get_mut(&index) else {
            return false;
        };
        if entry.winner == Some(lease) {
            entry.winner = None;
        }
        if entry.winner.is_none() {
            entry.foreign_commit = true;
        }
        true
    }

    /// The winner's full token sequence for a committed mirror row —
    /// resolves any comparison that arrived before the commit.
    pub fn note_committed(
        &self,
        index: GlobalIndex,
        lease: LeaseId,
        tokens: &[i32],
    ) {
        let mut g = self.inner.lock().unwrap();
        let (matches, divergences) = {
            let Some(entry) = g.rows.get_mut(&index) else {
                return;
            };
            if entry.mode != DupMode::Mirror
                || entry.winner != Some(lease)
            {
                return;
            }
            entry.winner_tokens = Some(tokens.to_vec());
            let pending = std::mem::take(&mut entry.pending);
            let mut matches = 0u64;
            let mut divergences = 0u64;
            for got in pending {
                if got.as_slice() == tokens {
                    matches += 1;
                } else {
                    divergences += 1;
                }
            }
            (matches, divergences)
        };
        g.counters.mirror_matches += matches;
        g.counters.mirror_divergences += divergences;
    }

    /// A mirror loser's full token sequence for `index`. Compared
    /// against the winner's committed tokens immediately if available,
    /// else parked until [`FleetRouter::note_committed`].
    pub fn resolve_mirror(&self, index: GlobalIndex, tokens: Vec<i32>) {
        let mut g = self.inner.lock().unwrap();
        let outcome = {
            let Some(entry) = g.rows.get_mut(&index) else {
                return;
            };
            if entry.mode != DupMode::Mirror {
                return;
            }
            match &entry.winner_tokens {
                Some(expected) => {
                    Some(expected.as_slice() == tokens.as_slice())
                }
                None => {
                    entry.pending.push(tokens);
                    None
                }
            }
        };
        match outcome {
            Some(true) => g.counters.mirror_matches += 1,
            Some(false) => g.counters.mirror_divergences += 1,
            None => {}
        }
    }

    /// Count decode tokens thrown away by hedge revocation / drops.
    pub fn note_dropped(&self, tokens: usize) {
        self.inner.lock().unwrap().counters.duplicated_tokens +=
            tokens as u64;
    }

    /// A lease left the registry (retired or revoked) — drop its
    /// routing metadata and resolve row entries it participated in.
    pub fn forget_lease(&self, lease: LeaseId) {
        let mut g = self.inner.lock().unwrap();
        g.leases.remove(&lease);
        let gone = HashSet::from([lease]);
        Self::scrub_partners(&mut g, &gone);
        Self::prune_rows(&mut g, &gone);
    }

    /// Remove departed leases from every survivor's partner list, so a
    /// primary whose hedge/mirror duplicate died becomes a candidate
    /// again instead of looking duplicated forever.
    fn scrub_partners(g: &mut Inner, gone: &HashSet<LeaseId>) {
        for meta in g.leases.values_mut() {
            meta.partners.retain(|p| !gone.contains(p));
        }
    }

    /// Drop row entries that can no longer affect routing: every
    /// departed (or no-longer-registered) lease is removed from
    /// `participants`; an entry stays only while more than one
    /// undecided participant remains, or a decided winner still has a
    /// live loser whose chunks must keep diverting, or a foreign
    /// commit still has participants whose chunks must divert.
    fn prune_rows(g: &mut Inner, gone: &HashSet<LeaseId>) {
        let Inner { rows, leases, .. } = g;
        rows.retain(|_, entry| {
            entry.participants
                .retain(|p| !gone.contains(p) && leases.contains_key(p));
            if entry.foreign_commit {
                return !entry.participants.is_empty();
            }
            match entry.winner {
                None => entry.participants.len() > 1,
                Some(w) => {
                    entry.participants.iter().any(|p| *p != w)
                        || !entry.pending.is_empty()
                }
            }
        });
    }

    /// A worker reported an engine failure for its lease (`fail_lease`
    /// verb — the fallback path). Returns the subset of the revoked
    /// lease's rows that must requeue (rows covered by a live
    /// duplicate or an already-committed winner do not).
    pub fn on_lease_failed(&self, revoked: &RevokedLease) -> Vec<GlobalIndex> {
        let mut g = self.inner.lock().unwrap();
        if let Some(e) = g.engines.get_mut(&revoked.owner) {
            e.errors += 1;
        }
        let dead = HashSet::from([revoked.id]);
        let mut handled = HashSet::new();
        let rows = Self::rows_to_requeue(
            &mut g,
            revoked.id,
            &revoked.rows,
            &dead,
            &mut handled,
        );
        g.counters.fallback_requeues += rows.len() as u64;
        g.leases.remove(&revoked.id);
        Self::scrub_partners(&mut g, &dead);
        Self::prune_rows(&mut g, &dead);
        rows
    }

    /// TTL sweep resolution: for each swept lease, which rows requeue.
    /// Dedup-safe when both sides of a duplicated pair expire in the
    /// same sweep — the shared row requeues exactly once.
    pub fn on_leases_swept(
        &self,
        swept: &[RevokedLease],
    ) -> Vec<(String, Vec<GlobalIndex>)> {
        let mut g = self.inner.lock().unwrap();
        let dead: HashSet<LeaseId> =
            swept.iter().map(|r| r.id).collect();
        let mut handled: HashSet<GlobalIndex> = HashSet::new();
        let mut out = Vec::new();
        for revoked in swept {
            let rows = Self::rows_to_requeue(
                &mut g,
                revoked.id,
                &revoked.rows,
                &dead,
                &mut handled,
            );
            if !rows.is_empty() {
                out.push((revoked.task.clone(), rows));
            }
        }
        for id in &dead {
            g.leases.remove(id);
        }
        Self::scrub_partners(&mut g, &dead);
        Self::prune_rows(&mut g, &dead);
        out
    }

    fn rows_to_requeue(
        g: &mut Inner,
        lease: LeaseId,
        undone: &[GlobalIndex],
        dead: &HashSet<LeaseId>,
        handled: &mut HashSet<GlobalIndex>,
    ) -> Vec<GlobalIndex> {
        let mut out = Vec::new();
        for idx in undone {
            if handled.contains(idx) {
                continue;
            }
            let requeue = match g.rows.get(idx) {
                None => true,
                Some(entry) => {
                    if entry.winner.is_some() || entry.foreign_commit {
                        // Already committed — by the other side of the
                        // pair, or by a foreign writer outside it.
                        false
                    } else {
                        // Requeue only if no other participant is both
                        // alive and outside this death set.
                        !entry.participants.iter().any(|p| {
                            *p != lease
                                && !dead.contains(p)
                                && g.leases.contains_key(p)
                        })
                    }
                }
            };
            if requeue {
                handled.insert(*idx);
                out.push(*idx);
            }
        }
        out
    }

    /// Routing-layer snapshot for `stats.fleet`.
    pub fn stats(&self) -> FleetStats {
        let g = self.inner.lock().unwrap();
        let mut sorted = g.intervals.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let mut engines: Vec<EngineStat> = g
            .engines
            .iter()
            .map(|(worker, e)| EngineStat {
                worker: worker.clone(),
                spec: e.spec.clone(),
                spec_reported: e.spec_reported,
                source: e.source.to_string(),
                chunks: e.chunks,
                tokens: e.tokens,
                errors: e.errors,
                hedge_rows_won: e.hedge_rows_won,
                hedge_rows_lost: e.hedge_rows_lost,
                observed_tps: e.observed_tps(),
            })
            .collect();
        engines.sort_by(|a, b| a.worker.cmp(&b.worker));
        FleetStats {
            routing: g.options.policy.name().to_string(),
            engines,
            chunk_time_p50_ms: percentile(&sorted, 0.50),
            chunk_time_p95_ms: percentile(&sorted, 0.95),
            hedge_budget_ms: Self::budget_ms(&g).unwrap_or(0.0),
            hedges_issued: g.counters.hedges_issued,
            hedge_rows_won_by_duplicate: g
                .counters
                .hedge_rows_won_by_duplicate,
            hedge_rows_won_by_primary: g
                .counters
                .hedge_rows_won_by_primary,
            duplicated_tokens: g.counters.duplicated_tokens,
            mirrors_issued: g.counters.mirrors_issued,
            mirror_matches: g.counters.mirror_matches,
            mirror_divergences: g.counters.mirror_divergences,
            lb_deferrals: g.counters.lb_deferrals,
            fallback_requeues: g.counters.fallback_requeues,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idx(v: u64) -> GlobalIndex {
        GlobalIndex(v)
    }

    fn revoked(
        id: LeaseId,
        task: &str,
        owner: &str,
        rows: &[u64],
    ) -> RevokedLease {
        RevokedLease {
            id,
            owner: owner.into(),
            task: task.into(),
            rows: rows.iter().map(|v| idx(*v)).collect(),
        }
    }

    fn hedge_router() -> FleetRouter {
        FleetRouter::new(FleetOptions {
            policy: RoutingPolicy::Hedge,
            hedge_min_samples: 1,
            hedge_min_ms: 0,
            hedge_factor: 0.0,
            ..FleetOptions::default()
        })
    }

    #[test]
    fn uncontested_rows_commit() {
        let r = FleetRouter::default();
        r.on_grant(1, "w0", "rollout");
        let (plans, claimed) =
            r.filter_chunk(1, &[(idx(0), false, 2), (idx(1), true, 3)]);
        assert_eq!(
            plans,
            vec![
                RowPlan::Commit { losers: vec![] },
                RowPlan::Commit { losers: vec![] }
            ]
        );
        assert!(claimed.is_empty(), "plain rows claim nothing");
    }

    #[test]
    fn hedge_winner_takes_row_and_loser_diverts() {
        let r = hedge_router();
        r.on_grant(1, "slow", "rollout");
        r.record_dup(1, 2, "fast", "rollout", &[idx(7)], DupMode::Hedge);

        // The duplicate finishes first: it commits and names the
        // straggler as the loser to discard.
        let (plans, claimed) = r.filter_chunk(2, &[(idx(7), true, 4)]);
        assert_eq!(plans, vec![RowPlan::Commit { losers: vec![1] }]);
        assert_eq!(claimed, vec![idx(7)]);
        r.confirm_claim(2, idx(7));

        // The straggler's late chunks for the row — partial or
        // finished — are dropped, never committed.
        let (plans, _) = r.filter_chunk(1, &[(idx(7), false, 2)]);
        assert_eq!(plans, vec![RowPlan::Drop]);
        let (plans, _) = r.filter_chunk(1, &[(idx(7), true, 2)]);
        assert_eq!(plans, vec![RowPlan::Drop]);

        let s = r.stats();
        assert_eq!(s.hedges_issued, 1);
        assert_eq!(s.hedge_rows_won_by_duplicate, 1);
        assert_eq!(s.hedge_rows_won_by_primary, 0);
    }

    #[test]
    fn hedge_primary_can_still_win() {
        let r = hedge_router();
        r.on_grant(1, "slow", "rollout");
        r.record_dup(1, 2, "fast", "rollout", &[idx(3)], DupMode::Hedge);
        let (plans, claimed) = r.filter_chunk(1, &[(idx(3), true, 4)]);
        assert_eq!(plans, vec![RowPlan::Commit { losers: vec![2] }]);
        assert_eq!(claimed, vec![idx(3)]);
        r.confirm_claim(1, idx(3));
        assert_eq!(
            r.filter_chunk(2, &[(idx(3), true, 4)]).0,
            vec![RowPlan::Drop]
        );
        assert_eq!(r.stats().hedge_rows_won_by_primary, 1);
    }

    #[test]
    fn rolled_back_claim_leaves_row_winnable_and_requeueable() {
        let r = hedge_router();
        r.on_grant(1, "slow", "rollout");
        r.record_dup(1, 2, "fast", "rollout", &[idx(7)], DupMode::Hedge);
        // The duplicate claims the win, but its commit fails
        // downstream: the claim is rolled back...
        let (plans, claimed) = r.filter_chunk(2, &[(idx(7), true, 4)]);
        assert_eq!(plans, vec![RowPlan::Commit { losers: vec![1] }]);
        r.rollback_claims(2, &claimed);
        // ...so the straggler can still win the row...
        let (plans, claimed) = r.filter_chunk(1, &[(idx(7), true, 4)]);
        assert_eq!(plans, vec![RowPlan::Commit { losers: vec![2] }]);
        r.rollback_claims(1, &claimed);
        // ...and with no commit landing anywhere, both deaths requeue
        // the row exactly once — it is not stranded behind a phantom
        // winner.
        let out = r.on_leases_swept(&[
            revoked(1, "rollout", "slow", &[7]),
            revoked(2, "rollout", "fast", &[7]),
        ]);
        let total: usize = out.iter().map(|(_, rows)| rows.len()).sum();
        assert_eq!(total, 1, "{out:?}");
    }

    #[test]
    fn unconfirmed_claim_counts_nothing() {
        let r = hedge_router();
        r.on_grant(1, "slow", "rollout");
        r.record_dup(1, 2, "fast", "rollout", &[idx(7)], DupMode::Hedge);
        r.filter_chunk(2, &[(idx(7), true, 4)]);
        let s = r.stats();
        assert_eq!(s.hedge_rows_won_by_duplicate, 0, "claim ≠ win");
        r.confirm_claim(2, idx(7));
        assert_eq!(r.stats().hedge_rows_won_by_duplicate, 1);
    }

    #[test]
    fn non_participant_lease_cannot_steal_a_duplicated_row() {
        let r = hedge_router();
        r.on_grant(1, "slow", "rollout");
        r.record_dup(1, 2, "fast", "rollout", &[idx(7)], DupMode::Hedge);
        // A third lease referencing the duplicated index gets Plain —
        // the lease table will reject the foreign row — and must NOT
        // take the winner slot.
        r.on_grant(3, "rogue", "rollout");
        let (plans, claimed) = r.filter_chunk(3, &[(idx(7), true, 4)]);
        assert_eq!(plans, vec![RowPlan::Commit { losers: vec![] }]);
        assert!(claimed.is_empty());
        // The real pair is unaffected: the duplicate still wins.
        let (plans, claimed) = r.filter_chunk(2, &[(idx(7), true, 4)]);
        assert_eq!(plans, vec![RowPlan::Commit { losers: vec![1] }]);
        assert_eq!(claimed, vec![idx(7)]);
    }

    #[test]
    fn foreign_commit_diverts_pair_and_blocks_requeue() {
        let r = hedge_router();
        r.on_grant(1, "slow", "rollout");
        r.record_dup(1, 2, "fast", "rollout", &[idx(7)], DupMode::Hedge);
        // Not a duplicated row -> the caller must treat the squatted
        // cell as a protocol violation.
        assert!(!r.note_foreign_commit(2, idx(99)));
        // The duplicated row committed outside the pair: both sides'
        // chunks divert...
        assert!(r.note_foreign_commit(2, idx(7)));
        assert_eq!(
            r.filter_chunk(2, &[(idx(7), true, 4)]).0,
            vec![RowPlan::Drop]
        );
        assert_eq!(
            r.filter_chunk(1, &[(idx(7), false, 1)]).0,
            vec![RowPlan::Drop]
        );
        // ...and neither side's death requeues the already-committed
        // row.
        let out = r.on_leases_swept(&[
            revoked(1, "rollout", "slow", &[7]),
            revoked(2, "rollout", "fast", &[7]),
        ]);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn candidate_reservation_blocks_concurrent_duplicates() {
        let r = hedge_router();
        r.on_grant(1, "slow", "rollout");
        r.filter_chunk(1, &[(idx(0), false, 1)]);
        std::thread::sleep(Duration::from_millis(5));
        // First idle poller reserves the straggler...
        assert_eq!(r.hedge_candidate("fast", "rollout"), Some(1));
        // ...so a second concurrent poller cannot double-hedge it.
        assert_eq!(r.hedge_candidate("other", "rollout"), None);
        // A failed grant releases the reservation; the candidate is
        // available again.
        r.release_duplicate(1);
        assert_eq!(r.hedge_candidate("other", "rollout"), Some(1));
        // record_dup consumes the reservation for good.
        r.record_dup(1, 2, "other", "rollout", &[idx(0)], DupMode::Hedge);
        assert_eq!(r.hedge_candidate("fast", "rollout"), None);
    }

    #[test]
    fn mirror_reservation_counts_toward_fanout() {
        let r = FleetRouter::new(FleetOptions {
            policy: RoutingPolicy::Mirror,
            mirror_fanout: 2,
            ..FleetOptions::default()
        });
        r.on_grant(1, "a", "rollout");
        assert_eq!(r.mirror_candidate("b", "rollout"), Some(1));
        // Reservation outstanding: a concurrent poller must not
        // over-fan the mirror.
        assert_eq!(r.mirror_candidate("c", "rollout"), None);
        r.record_dup(1, 2, "b", "rollout", &[idx(0)], DupMode::Mirror);
        assert_eq!(r.mirror_candidate("c", "rollout"), None, "fanout cap");
    }

    #[test]
    fn hedge_candidate_requires_silence_and_other_worker() {
        let r = hedge_router();
        r.note_poll("slow", None);
        r.on_grant(1, "slow", "rollout");
        // Seed the interval distribution.
        r.filter_chunk(1, &[(idx(0), false, 1)]);
        std::thread::sleep(Duration::from_millis(5));
        // Same worker never hedges itself.
        assert_eq!(r.hedge_candidate("slow", "rollout"), None);
        assert_eq!(r.hedge_candidate("fast", "rollout"), Some(1));
        // Once duplicated, the lease is no longer a candidate.
        r.record_dup(1, 2, "fast", "rollout", &[idx(0)], DupMode::Hedge);
        assert_eq!(r.hedge_candidate("other", "rollout"), None);
    }

    #[test]
    fn hedge_budget_needs_samples() {
        let r = FleetRouter::new(FleetOptions {
            policy: RoutingPolicy::Hedge,
            hedge_min_samples: 4,
            ..FleetOptions::default()
        });
        r.on_grant(1, "slow", "rollout");
        r.filter_chunk(1, &[(idx(0), false, 1)]);
        assert_eq!(
            r.hedge_candidate("fast", "rollout"),
            None,
            "distribution not warm"
        );
        assert_eq!(r.stats().hedge_budget_ms, 0.0);
    }

    #[test]
    fn sweep_requeues_shared_row_exactly_once() {
        let r = hedge_router();
        r.on_grant(1, "slow", "rollout");
        r.record_dup(1, 2, "fast", "rollout", &[idx(5)], DupMode::Hedge);
        // Both sides of the pair die in one sweep: row 5 requeues once.
        let out = r.on_leases_swept(&[
            revoked(1, "rollout", "slow", &[5]),
            revoked(2, "rollout", "fast", &[5]),
        ]);
        let total: usize = out.iter().map(|(_, rows)| rows.len()).sum();
        assert_eq!(total, 1);
    }

    #[test]
    fn sweep_skips_rows_covered_by_live_partner() {
        let r = hedge_router();
        r.on_grant(1, "slow", "rollout");
        r.record_dup(1, 2, "fast", "rollout", &[idx(5)], DupMode::Hedge);
        // Only the straggler dies; the duplicate still decodes row 5.
        let out =
            r.on_leases_swept(&[revoked(1, "rollout", "slow", &[5])]);
        assert!(out.is_empty(), "live duplicate covers the row: {out:?}");
        // When the survivor later dies too, the row requeues.
        let out =
            r.on_leases_swept(&[revoked(2, "rollout", "fast", &[5])]);
        assert_eq!(out, vec![("rollout".to_string(), vec![idx(5)])]);
    }

    #[test]
    fn sweep_skips_rows_already_won() {
        let r = hedge_router();
        r.on_grant(1, "slow", "rollout");
        r.record_dup(1, 2, "fast", "rollout", &[idx(5)], DupMode::Hedge);
        assert_eq!(
            r.filter_chunk(2, &[(idx(5), true, 4)]).0,
            vec![RowPlan::Commit { losers: vec![1] }]
        );
        r.confirm_claim(2, idx(5));
        // Straggler expires afterwards: its copy of row 5 must NOT
        // requeue — the row already trained via the duplicate.
        let out =
            r.on_leases_swept(&[revoked(1, "rollout", "slow", &[5])]);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn fallback_requeues_unshared_rows_immediately() {
        let r = FleetRouter::new(FleetOptions {
            policy: RoutingPolicy::Fallback,
            ..FleetOptions::default()
        });
        r.note_poll("w0", None);
        r.on_grant(1, "w0", "rollout");
        let rows =
            r.on_lease_failed(&revoked(1, "rollout", "w0", &[1, 2]));
        assert_eq!(rows, vec![idx(1), idx(2)]);
        let s = r.stats();
        assert_eq!(s.fallback_requeues, 2);
        assert_eq!(s.engines[0].errors, 1);
    }

    #[test]
    fn mirror_compare_resolves_in_either_order() {
        let r = FleetRouter::new(FleetOptions {
            policy: RoutingPolicy::Mirror,
            ..FleetOptions::default()
        });
        r.on_grant(1, "a", "rollout");
        r.record_dup(
            1,
            2,
            "b",
            "rollout",
            &[idx(0), idx(1)],
            DupMode::Mirror,
        );

        // Row 0: winner commits first, loser compares after — a match.
        assert_eq!(
            r.filter_chunk(1, &[(idx(0), true, 3)]).0,
            vec![RowPlan::Commit { losers: vec![] }]
        );
        r.note_committed(idx(0), 1, &[10, 11, 12]);
        assert_eq!(
            r.filter_chunk(2, &[(idx(0), true, 3)]).0,
            vec![RowPlan::Compare]
        );
        r.resolve_mirror(idx(0), vec![10, 11, 12]);

        // Row 1: the loser's comparison arrives while the winner's
        // commit is still in flight — parked, then resolved as a
        // divergence.
        assert_eq!(
            r.filter_chunk(2, &[(idx(1), true, 3)]).0,
            vec![RowPlan::Commit { losers: vec![] }]
        );
        assert_eq!(
            r.filter_chunk(1, &[(idx(1), true, 3)]).0,
            vec![RowPlan::Compare]
        );
        r.resolve_mirror(idx(1), vec![7, 7, 7]);
        r.note_committed(idx(1), 2, &[8, 8, 8]);

        let s = r.stats();
        assert_eq!(s.mirrors_issued, 1);
        assert_eq!(s.mirror_matches, 1);
        assert_eq!(s.mirror_divergences, 1);
    }

    #[test]
    fn mirror_candidate_respects_fanout() {
        let r = FleetRouter::new(FleetOptions {
            policy: RoutingPolicy::Mirror,
            mirror_fanout: 2,
            ..FleetOptions::default()
        });
        r.on_grant(1, "a", "rollout");
        assert_eq!(
            r.mirror_candidate("a", "rollout"),
            None,
            "same worker"
        );
        assert_eq!(r.mirror_candidate("b", "rollout"), Some(1));
        r.record_dup(1, 2, "b", "rollout", &[idx(0)], DupMode::Mirror);
        assert_eq!(
            r.mirror_candidate("c", "rollout"),
            None,
            "fanout cap"
        );
    }

    #[test]
    fn dead_duplicate_reopens_primary_for_hedging() {
        let r = hedge_router();
        r.on_grant(1, "slow", "rollout");
        r.record_dup(1, 2, "fast", "rollout", &[idx(5)], DupMode::Hedge);
        // The duplicate dies alone; the straggler is still stuck — it
        // must become hedge-able again, not look duplicated forever.
        r.on_leases_swept(&[revoked(2, "rollout", "fast", &[5])]);
        r.filter_chunk(1, &[(idx(5), false, 1)]);
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(r.hedge_candidate("other", "rollout"), Some(1));
    }

    #[test]
    fn lb_defers_only_loaded_workers_with_idler_peers() {
        let r = FleetRouter::default();
        r.note_poll("busy", None);
        r.note_poll("idle", None);
        let mut load = HashMap::new();
        load.insert("busy".to_string(), (2usize, 16usize));
        load.insert("idle".to_string(), (0usize, 0usize));
        assert!(r.should_defer("busy", &load));
        assert!(
            !r.should_defer("idle", &load),
            "least-loaded never defers"
        );
        let s = r.stats();
        assert_eq!(s.lb_deferrals, 1);
    }

    #[test]
    fn forget_lease_clears_row_entries() {
        let r = hedge_router();
        r.on_grant(1, "a", "rollout");
        r.record_dup(1, 2, "b", "rollout", &[idx(9)], DupMode::Hedge);
        r.filter_chunk(2, &[(idx(9), true, 1)]);
        r.forget_lease(1);
        r.forget_lease(2);
        // Entry gone: a fresh lease on the same index commits normally.
        r.on_grant(3, "c", "rollout");
        assert_eq!(
            r.filter_chunk(3, &[(idx(9), true, 1)]).0,
            vec![RowPlan::Commit { losers: vec![] }]
        );
    }
}
