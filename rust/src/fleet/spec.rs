//! Capability model: what an engine *is* — kind, geometry, relative
//! speed class, free-form tags — independent of where it runs. The
//! routing layer filters candidates on these specs, so a mixed fleet
//! (fast/cheap mock next to slow/accurate XLA) is data, not plumbing.

use anyhow::{bail, Result};

use crate::runtime::PolicyEngine;

/// Relative speed class of an engine — a coarse routing hint, derived
/// from the well-known tags (`fast-cheap`, `slow-accurate`) unless set
/// explicitly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SpeedClass {
    Fast,
    #[default]
    Standard,
    Slow,
}

impl SpeedClass {
    pub fn name(self) -> &'static str {
        match self {
            SpeedClass::Fast => "fast",
            SpeedClass::Standard => "standard",
            SpeedClass::Slow => "slow",
        }
    }

    pub fn parse(s: &str) -> Result<SpeedClass> {
        Ok(match s {
            "fast" => SpeedClass::Fast,
            "standard" => SpeedClass::Standard,
            "slow" => SpeedClass::Slow,
            other => bail!("unknown speed class {other:?} (fast|standard|slow)"),
        })
    }

    /// Infer the class from well-known tags (`fast-cheap` ⇒ fast,
    /// `slow-accurate` ⇒ slow); anything else is standard.
    pub fn from_tags(tags: &[String]) -> SpeedClass {
        if tags.iter().any(|t| t == "fast-cheap" || t == "fast") {
            SpeedClass::Fast
        } else if tags.iter().any(|t| t == "slow-accurate" || t == "slow") {
            SpeedClass::Slow
        } else {
            SpeedClass::Standard
        }
    }
}

/// Capability report for one engine: the registry's unit of modeling.
///
/// Specs enter the fleet registry two ways: statically from the
/// `[fleet]` config table, or dynamically at worker attach — the worker
/// builds one from its engine ([`EngineSpec::of_engine`]) and rides it
/// on `lease_prompts`; the coordinator re-exports it through
/// `worker_stats` so `asyncflow info --connect` can render the fleet.
#[derive(Debug, Clone, PartialEq)]
pub struct EngineSpec {
    /// Backend kind (`"mock"`, `"xla"`, …) — [`PolicyEngine::kind`].
    pub kind: String,
    /// Fixed micro-batch width baked into the backend.
    pub batch: usize,
    /// Prompt length the backend was compiled for.
    pub prompt_len: usize,
    /// Max trajectory length (prompt + response).
    pub max_len: usize,
    /// Coarse routing hint (derived from tags unless set explicitly).
    pub speed: SpeedClass,
    /// Free-form capability tags (`fast-cheap`, `slow-accurate`,
    /// `mock`, `xla`, …).
    pub tags: Vec<String>,
    /// Observed decode throughput in tokens/sec (0 = not yet measured).
    /// Workers may report their own; the coordinator refines it from
    /// committed chunks either way.
    pub observed_tps: f64,
}

impl EngineSpec {
    pub fn new(
        kind: impl Into<String>,
        batch: usize,
        prompt_len: usize,
        max_len: usize,
    ) -> Self {
        EngineSpec {
            kind: kind.into(),
            batch,
            prompt_len,
            max_len,
            speed: SpeedClass::Standard,
            tags: Vec::new(),
            observed_tps: 0.0,
        }
    }

    /// Capability report of a live engine, with operator-assigned tags.
    pub fn of_engine(engine: &dyn PolicyEngine, tags: Vec<String>) -> Self {
        EngineSpec::new(
            engine.kind(),
            engine.batch_size(),
            engine.prompt_len(),
            engine.max_len(),
        )
        .with_tags(tags)
    }

    /// Attach tags, re-deriving the speed class from them.
    pub fn with_tags(mut self, tags: Vec<String>) -> Self {
        self.speed = SpeedClass::from_tags(&tags);
        self.tags = tags;
        self
    }

    /// Whether this engine can take over work leased against `other`:
    /// its compiled geometry must cover the other's prompts and decode
    /// budget. The basis of hedge/mirror candidate filtering.
    pub fn can_stand_in_for(&self, other: &EngineSpec) -> bool {
        self.batch >= 1
            && self.prompt_len >= other.prompt_len
            && self.max_len >= other.max_len
    }

    /// Parse a comma-separated tag list (the `--engine-tags` form);
    /// empty segments are dropped.
    pub fn parse_tags(s: &str) -> Vec<String> {
        s.split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(str::to_string)
            .collect()
    }
}

/// Routing policy over lease dispatch — how the coordinator uses the
/// fleet registry when granting work.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutingPolicy {
    /// Grant to the least-outstanding capable candidate: a loaded
    /// worker's poll is deferred while a strictly less-loaded peer is
    /// actively polling.
    #[default]
    LoadBalance,
    /// Like load-balance, plus workers route engine errors through
    /// `fail_lease` so a failed lease requeues to the next candidate
    /// immediately instead of waiting out its TTL.
    Fallback,
    /// Duplicate a straggler lease's remaining rows to a second capable
    /// engine once its decode exceeds the fleet's latency budget;
    /// whichever engine finishes a row first commits it, the loser's
    /// copy is revoked.
    Hedge,
    /// Duplicate every lease to a second engine and compare finished
    /// outputs against the committed cells — the engine-correctness
    /// soak-test mode.
    Mirror,
}

impl RoutingPolicy {
    pub fn name(self) -> &'static str {
        match self {
            RoutingPolicy::LoadBalance => "lb",
            RoutingPolicy::Fallback => "fallback",
            RoutingPolicy::Hedge => "hedge",
            RoutingPolicy::Mirror => "mirror",
        }
    }

    pub fn parse(s: &str) -> Result<RoutingPolicy> {
        Ok(match s {
            "lb" | "load-balance" | "load_balance" => {
                RoutingPolicy::LoadBalance
            }
            "fallback" => RoutingPolicy::Fallback,
            "hedge" => RoutingPolicy::Hedge,
            "mirror" => RoutingPolicy::Mirror,
            other => {
                bail!("unknown routing policy {other:?} (lb|fallback|hedge|mirror)")
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::MockEngine;

    #[test]
    fn speed_class_derives_from_tags() {
        let fast = EngineSpec::new("mock", 8, 16, 48)
            .with_tags(vec!["fast-cheap".into(), "mock".into()]);
        assert_eq!(fast.speed, SpeedClass::Fast);
        let slow = EngineSpec::new("xla", 8, 16, 48)
            .with_tags(vec!["slow-accurate".into()]);
        assert_eq!(slow.speed, SpeedClass::Slow);
        let std = EngineSpec::new("xla", 8, 16, 48)
            .with_tags(vec!["gpu".into()]);
        assert_eq!(std.speed, SpeedClass::Standard);
    }

    #[test]
    fn of_engine_reports_geometry_and_kind() {
        let e = MockEngine::new(4, 8, 24);
        let spec = EngineSpec::of_engine(&e, vec!["mock".into()]);
        assert_eq!(spec.kind, "mock");
        assert_eq!(spec.batch, 4);
        assert_eq!(spec.prompt_len, 8);
        assert_eq!(spec.max_len, 24);
    }

    #[test]
    fn stand_in_requires_covering_geometry() {
        let small = EngineSpec::new("mock", 8, 8, 24);
        let big = EngineSpec::new("mock", 8, 16, 48);
        assert!(big.can_stand_in_for(&small));
        assert!(!small.can_stand_in_for(&big), "shorter geometry");
        assert!(big.can_stand_in_for(&big));
    }

    #[test]
    fn tags_parse_and_policy_parse() {
        assert_eq!(
            EngineSpec::parse_tags("fast-cheap, mock,,x"),
            vec!["fast-cheap", "mock", "x"]
        );
        assert!(EngineSpec::parse_tags("").is_empty());
        assert_eq!(
            RoutingPolicy::parse("lb").unwrap(),
            RoutingPolicy::LoadBalance
        );
        assert_eq!(
            RoutingPolicy::parse("hedge").unwrap(),
            RoutingPolicy::Hedge
        );
        assert!(RoutingPolicy::parse("coinflip").is_err());
        for p in [
            RoutingPolicy::LoadBalance,
            RoutingPolicy::Fallback,
            RoutingPolicy::Hedge,
            RoutingPolicy::Mirror,
        ] {
            assert_eq!(RoutingPolicy::parse(p.name()).unwrap(), p);
        }
    }
}
