//! Workload substrate: synthetic verifiable math tasks + byte tokenizer.
//!
//! Stand-in for the paper's DeepScaleR dataset (see DESIGN.md
//! §Substitutions): GRPO needs prompts with *programmatically verifiable*
//! answers, which integer arithmetic provides exactly — the reward path
//! (parse the generated answer, compare) is the same rule-based check the
//! paper's math workload uses.
//!
//! Prompts are rendered to a fixed width (left-padded) so the AOT prefill
//! artifact's static `[B, P]` geometry holds, and answers terminate with
//! a newline EOS.

use crate::util::rng::Rng;

/// Byte-level tokenizer: token id == byte value. PAD=0, EOS='\n'.
pub const PAD: i32 = 0;
pub const EOS: i32 = b'\n' as i32;

/// Encode text to byte tokens.
pub fn encode(text: &str) -> Vec<i32> {
    text.bytes().map(|b| b as i32).collect()
}

/// Decode tokens to text, stopping at PAD/EOS; non-ASCII bytes map to '?'.
pub fn decode(tokens: &[i32]) -> String {
    tokens
        .iter()
        .take_while(|&&t| t != PAD && t != EOS)
        .map(|&t| {
            if (1..=255).contains(&t) {
                t as u8 as char
            } else {
                '?'
            }
        })
        .collect()
}

/// One verifiable task: fixed-width prompt tokens + ground-truth answer.
#[derive(Debug, Clone, PartialEq)]
pub struct MathTask {
    pub prompt_text: String,
    pub prompt_tokens: Vec<i32>,
    pub answer: i64,
}

/// Arithmetic task generator.
#[derive(Debug, Clone)]
pub struct MathTaskGen {
    rng: Rng,
    prompt_len: usize,
    max_operand: u64,
    ops: Vec<char>,
}

impl MathTaskGen {
    pub fn new(seed: u64, prompt_len: usize) -> Self {
        MathTaskGen {
            rng: Rng::new(seed),
            prompt_len,
            max_operand: 99,
            ops: vec!['+', '-'],
        }
    }

    /// Minimum prompt width the current difficulty needs:
    /// `"Q:" + operand + op + operand + "=? A:"`.
    pub fn min_prompt_len(&self) -> usize {
        2 * self.max_operand.to_string().len() + 8
    }

    /// Check the configured prompt width fits the task format.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.prompt_len >= self.min_prompt_len(),
            "prompt_len {} too small for math tasks (need >= {})",
            self.prompt_len,
            self.min_prompt_len()
        );
        Ok(())
    }

    pub fn with_difficulty(mut self, max_operand: u64, mul: bool) -> Self {
        self.max_operand = max_operand;
        if mul && !self.ops.contains(&'*') {
            self.ops.push('*');
        }
        self
    }

    /// Generate the next task. Prompt format (before left-padding):
    /// `Q:047+012=? A:` — operands zero-padded to the max-operand width.
    pub fn next_task(&mut self) -> MathTask {
        let width = self.max_operand.to_string().len();
        let a = self.rng.range_u64(0, self.max_operand) as i64;
        let b = self.rng.range_u64(0, self.max_operand) as i64;
        let op = self.ops[self.rng.below(self.ops.len())];
        let answer = match op {
            '+' => a + b,
            '-' => a - b,
            '*' => a * b,
            _ => unreachable!(),
        };
        let body = format!("Q:{a:0width$}{op}{b:0width$}=? A:");
        assert!(
            body.len() <= self.prompt_len,
            "prompt_len {} too small for task body {:?}",
            self.prompt_len,
            body
        );
        let prompt_text =
            format!("{}{}", " ".repeat(self.prompt_len - body.len()), body);
        let prompt_tokens = encode(&prompt_text);
        debug_assert_eq!(prompt_tokens.len(), self.prompt_len);
        MathTask { prompt_text, prompt_tokens, answer }
    }
}

/// Rule-based reward for a generated response (paper: verifiable-answer
/// scoring), with dense shaping so GRPO groups don't collapse to
/// all-zero advantage when the policy starts from scratch:
///
/// * up to 0.2 — fraction of (trimmed) response characters that are
///   numeric (`0-9` or a leading `-`);
/// * +0.3 — the response parses as an integer;
/// * +0.5 — the parsed integer equals the ground truth
///   (total 1.0 for an exact well-formed answer).
pub fn grade_response(response_tokens: &[i32], answer: i64) -> f32 {
    let text = decode(response_tokens);
    let trimmed = text.trim();
    if trimmed.is_empty() {
        return 0.0;
    }
    let numeric = trimmed
        .chars()
        .enumerate()
        .filter(|(i, c)| c.is_ascii_digit() || (*i == 0 && *c == '-'))
        .count();
    let mut reward = 0.2 * numeric as f32 / trimmed.len() as f32;
    if let Ok(v) = trimmed.parse::<i64>() {
        reward += 0.3;
        if v == answer {
            reward += 0.5;
        }
    }
    reward
}

/// Render an answer the way the target policy should produce it.
pub fn render_answer(answer: i64) -> Vec<i32> {
    let mut toks = encode(&answer.to_string());
    toks.push(EOS);
    toks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        let toks = encode("Q:12+34=? A:");
        assert_eq!(decode(&toks), "Q:12+34=? A:");
    }

    #[test]
    fn decode_stops_at_eos_and_pad() {
        let mut toks = encode("42");
        toks.push(EOS);
        toks.extend_from_slice(&[PAD, PAD]);
        assert_eq!(decode(&toks), "42");
    }

    #[test]
    fn prompts_have_fixed_width() {
        let mut g = MathTaskGen::new(0, 32);
        for _ in 0..100 {
            let t = g.next_task();
            assert_eq!(t.prompt_tokens.len(), 32);
            assert!(t.prompt_text.ends_with("=? A:"));
        }
    }

    #[test]
    fn answers_are_correct() {
        let mut g = MathTaskGen::new(1, 32);
        for _ in 0..100 {
            let t = g.next_task();
            // Re-parse the prompt and check the arithmetic.
            let body = t.prompt_text.trim_start();
            let expr = &body[2..body.len() - 5]; // strip "Q:" and "=? A:"
            let (a, op, b) = if let Some(p) = expr.find('+') {
                (&expr[..p], '+', &expr[p + 1..])
            } else {
                let p = expr.rfind('-').unwrap();
                (&expr[..p], '-', &expr[p + 1..])
            };
            let a: i64 = a.parse().unwrap();
            let b: i64 = b.parse().unwrap();
            let want = if op == '+' { a + b } else { a - b };
            assert_eq!(t.answer, want, "prompt {:?}", t.prompt_text);
        }
    }

    #[test]
    fn grading_tiers() {
        // exact, well-formed
        assert_eq!(grade_response(&render_answer(46), 46), 1.0);
        assert_eq!(grade_response(&encode(" 46 "), 46), 1.0);
        assert_eq!(grade_response(&render_answer(-3), -3), 1.0);
        // parseable but wrong: 0.2 (all digits) + 0.3 (parses)
        assert!((grade_response(&render_answer(45), 46) - 0.5).abs() < 1e-6);
        // non-numeric garbage
        assert_eq!(grade_response(&encode("banana"), 46), 0.0);
        assert_eq!(grade_response(&[], 46), 0.0);
        // partial digit credit, no parse
        let partial = grade_response(&encode("4x6b"), 46);
        assert!(partial > 0.0 && partial < 0.2, "partial={partial}");
        // shaping is monotone toward well-formedness
        assert!(
            grade_response(&render_answer(45), 46)
                > grade_response(&encode("4x6b"), 46)
        );
    }

    #[test]
    fn generator_is_deterministic() {
        let mut a = MathTaskGen::new(7, 32);
        let mut b = MathTaskGen::new(7, 32);
        for _ in 0..10 {
            assert_eq!(a.next_task(), b.next_task());
        }
    }

    #[test]
    fn difficulty_widens_operands() {
        let mut g = MathTaskGen::new(0, 32).with_difficulty(999, true);
        let mut saw_mul = false;
        for _ in 0..200 {
            let t = g.next_task();
            saw_mul |= t.prompt_text.contains('*');
        }
        assert!(saw_mul);
    }
}
