//! Preemption-trace generation: an Ornstein–Uhlenbeck spot-price
//! process mapped through per-process-kind preemption thresholds to a
//! deterministic schedule of kill events.
//!
//! The model follows the spot-market framing: a single mean-reverting
//! "price" path is simulated over the chaos horizon, and each process
//! kind (rollout worker, storage unit, pipeline stage) carries its own
//! preemption threshold — when the price is above a kind's threshold
//! the market "reclaims" one instance of that kind. Lower thresholds
//! mean cheaper bids and therefore *more* preemptions; the schedule is
//! fully determined by the seed (the price path consumes randomness,
//! threshold crossings do not), so a chaos run replays bit-identically
//! under `--seed`.

use crate::util::rng::Rng;

/// Which population a kill event targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ProcessKind {
    /// Elastic rollout worker (`asyncflow rollout-worker`).
    Worker,
    /// Data-plane storage unit (`asyncflow storage-unit`).
    Unit,
    /// TCP pipeline stage (`asyncflow stage`).
    Stage,
}

impl ProcessKind {
    pub const ALL: [ProcessKind; 3] =
        [ProcessKind::Worker, ProcessKind::Unit, ProcessKind::Stage];

    pub fn name(self) -> &'static str {
        match self {
            ProcessKind::Worker => "worker",
            ProcessKind::Unit => "unit",
            ProcessKind::Stage => "stage",
        }
    }
}

/// Ornstein–Uhlenbeck parameters for the spot-price path:
/// `dx = reversion * (mean - x) * dt + sigma * sqrt(dt) * N(0,1)`,
/// stepped every `dt_ms` with `dt = dt_ms / 1000`.
#[derive(Debug, Clone)]
pub struct OuParams {
    /// Long-run mean the price reverts to.
    pub mean: f64,
    /// Reversion rate (per second): how hard excursions get pulled back.
    pub reversion: f64,
    /// Diffusion scale (per sqrt-second).
    pub sigma: f64,
    /// Step width of the simulated path, in milliseconds.
    pub dt_ms: u64,
    /// Price at t=0.
    pub start: f64,
}

impl Default for OuParams {
    fn default() -> Self {
        OuParams {
            mean: 1.0,
            reversion: 0.6,
            sigma: 0.55,
            dt_ms: 250,
            start: 1.0,
        }
    }
}

/// The discretized OU process (Euler–Maruyama), seeded and
/// deterministic.
pub struct OuProcess {
    params: OuParams,
    x: f64,
    rng: Rng,
}

impl OuProcess {
    pub fn new(params: OuParams, seed: u64) -> Self {
        let x = params.start;
        // Domain-separate from other consumers of the seed ("ou" tag).
        let mut base = Rng::new(seed);
        OuProcess { params, x, rng: base.fork(0x6f75) }
    }

    /// Current price.
    pub fn price(&self) -> f64 {
        self.x
    }

    /// Advance one `dt_ms` step and return the new price.
    pub fn step(&mut self) -> f64 {
        let dt = self.params.dt_ms as f64 / 1000.0;
        let drift = self.params.reversion * (self.params.mean - self.x) * dt;
        let shock = self.params.sigma * dt.sqrt() * self.rng.normal();
        self.x += drift + shock;
        self.x
    }
}

/// Per-kind preemption thresholds: an instance of a kind is killed
/// while the spot price sits above its threshold. Lower threshold ⇒
/// preempted more often (a cheaper bid).
#[derive(Debug, Clone)]
pub struct KillThresholds {
    pub worker: f64,
    pub unit: f64,
    pub stage: f64,
}

impl Default for KillThresholds {
    fn default() -> Self {
        // Workers are the cheapest bid (most churn); storage units the
        // most protected.
        KillThresholds { worker: 1.15, unit: 1.55, stage: 1.35 }
    }
}

impl KillThresholds {
    pub fn for_kind(&self, kind: ProcessKind) -> f64 {
        match kind {
            ProcessKind::Worker => self.worker,
            ProcessKind::Unit => self.unit,
            ProcessKind::Stage => self.stage,
        }
    }
}

/// One scheduled kill: at `at_ms` (relative to chaos-phase start) one
/// live instance of `kind` receives SIGKILL. The spot price at the
/// crossing rides along for reports.
#[derive(Debug, Clone)]
pub struct ChaosEvent {
    pub at_ms: u64,
    pub kind: ProcessKind,
    pub price: f64,
}

impl ChaosEvent {
    /// Stable label used in violation reports ("which event preceded
    /// this check").
    pub fn label(&self) -> String {
        format!("kill-{}@{}ms", self.kind.name(), self.at_ms)
    }
}

/// A generated schedule: kill events sorted by time over `horizon_ms`.
#[derive(Debug, Clone, Default)]
pub struct ChaosSchedule {
    pub events: Vec<ChaosEvent>,
    pub horizon_ms: u64,
}

impl ChaosSchedule {
    /// Simulate the price path and emit kill events at threshold
    /// crossings. `min_gap_ms` rate-limits kills per kind so a long
    /// excursion above a threshold doesn't machine-gun one population
    /// (0 = a kill at every step above threshold).
    pub fn generate(
        seed: u64,
        horizon_ms: u64,
        params: &OuParams,
        thresholds: &KillThresholds,
        min_gap_ms: u64,
    ) -> Self {
        let mut ou = OuProcess::new(params.clone(), seed);
        let mut events = Vec::new();
        // Last kill time per kind, for the rate limit. `None` = never.
        let mut last: [Option<u64>; 3] = [None; 3];
        let mut t = params.dt_ms;
        while t <= horizon_ms {
            let price = ou.step();
            for (i, kind) in ProcessKind::ALL.into_iter().enumerate() {
                if price <= thresholds.for_kind(kind) {
                    continue;
                }
                let ok_gap = match last[i] {
                    None => true,
                    Some(prev) => t - prev >= min_gap_ms.max(1),
                };
                if ok_gap {
                    events.push(ChaosEvent { at_ms: t, kind, price });
                    last[i] = Some(t);
                }
            }
            t += params.dt_ms;
        }
        ChaosSchedule { events, horizon_ms }
    }

    /// Number of scheduled kills targeting `kind`.
    pub fn kills_of(&self, kind: ProcessKind) -> usize {
        self.events.iter().filter(|e| e.kind == kind).count()
    }

    /// Process kinds with at least one scheduled kill.
    pub fn kinds_covered(&self) -> usize {
        ProcessKind::ALL
            .into_iter()
            .filter(|&k| self.kills_of(k) > 0)
            .count()
    }

    /// Pad the schedule (deterministically, no randomness) until it has
    /// at least `min_total` events AND covers all three process kinds —
    /// the smoke-run floor. Padded events are placed evenly across the
    /// horizon and stamped with the kind's own threshold as the price
    /// (the market price a real crossing would have had).
    pub fn ensure_floor(
        &mut self,
        min_total: usize,
        thresholds: &KillThresholds,
    ) {
        let mut i = 0usize;
        while self.kinds_covered() < ProcessKind::ALL.len()
            || self.events.len() < min_total
        {
            let kind = ProcessKind::ALL
                .into_iter()
                .find(|&k| self.kills_of(k) == 0)
                .unwrap_or(ProcessKind::ALL[i % ProcessKind::ALL.len()]);
            let slots = (min_total as u64).max(3) + 1;
            let at_ms = ((i as u64 % slots) + 1) * self.horizon_ms / slots;
            self.events.push(ChaosEvent {
                at_ms: at_ms.max(1),
                kind,
                price: thresholds.for_kind(kind),
            });
            i += 1;
        }
        self.events.sort_by_key(|e| e.at_ms);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ou_reverts_to_mean_without_noise() {
        // sigma = 0 makes the process a pure exponential decay toward
        // the mean: the distance must shrink every step.
        let params = OuParams {
            mean: 1.0,
            reversion: 0.8,
            sigma: 0.0,
            dt_ms: 250,
            start: 5.0,
        };
        let mut ou = OuProcess::new(params, 42);
        let mut dist = (ou.price() - 1.0).abs();
        for _ in 0..40 {
            ou.step();
            let d = (ou.price() - 1.0).abs();
            assert!(d < dist, "distance to mean must shrink: {d} >= {dist}");
            dist = d;
        }
        assert!(dist < 0.01, "should be at the mean after 10s, got {dist}");
    }

    #[test]
    fn ou_long_run_average_tracks_mean() {
        let params = OuParams {
            mean: 2.0,
            reversion: 1.0,
            sigma: 0.3,
            dt_ms: 100,
            start: 2.0,
        };
        let mut ou = OuProcess::new(params, 7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += ou.step();
        }
        let avg = sum / n as f64;
        // Stationary mean is `mean`; stationary sd = sigma/sqrt(2k) ≈
        // 0.21, so the sample average over 2000s is tight around 2.0.
        assert!(
            (avg - 2.0).abs() < 0.15,
            "long-run average {avg} drifted from the OU mean 2.0"
        );
    }

    #[test]
    fn schedule_replays_deterministically_under_fixed_seed() {
        let params = OuParams::default();
        let thr = KillThresholds::default();
        let a = ChaosSchedule::generate(1234, 60_000, &params, &thr, 500);
        let b = ChaosSchedule::generate(1234, 60_000, &params, &thr, 500);
        assert_eq!(a.events.len(), b.events.len());
        for (x, y) in a.events.iter().zip(&b.events) {
            assert_eq!(x.at_ms, y.at_ms);
            assert_eq!(x.kind, y.kind);
            assert!((x.price - y.price).abs() == 0.0);
        }
        let c = ChaosSchedule::generate(999, 60_000, &params, &thr, 500);
        let same = a.events.len() == c.events.len()
            && a.events
                .iter()
                .zip(&c.events)
                .all(|(x, y)| x.at_ms == y.at_ms && x.kind == y.kind);
        assert!(
            !same || a.events.is_empty(),
            "different seeds should give different schedules"
        );
    }

    #[test]
    fn kill_density_scales_monotonically_with_threshold() {
        // Same seed ⇒ same price path (crossings consume no
        // randomness), so a lower threshold sees a superset of the
        // steps above it: kill count is monotone non-increasing in the
        // threshold, and strictly more kills show up at the low end.
        let params = OuParams::default();
        let mut counts = Vec::new();
        for thr in [0.8, 1.0, 1.2, 1.4, 1.8] {
            let t = KillThresholds { worker: thr, unit: 99.0, stage: 99.0 };
            let s = ChaosSchedule::generate(7, 120_000, &params, &t, 0);
            counts.push(s.kills_of(ProcessKind::Worker));
        }
        for w in counts.windows(2) {
            assert!(
                w[0] >= w[1],
                "kill density must not increase with threshold: {counts:?}"
            );
        }
        assert!(
            counts[0] > counts[counts.len() - 1],
            "0.8 vs 1.8 thresholds should differ in kill count: {counts:?}"
        );
    }

    #[test]
    fn ensure_floor_pads_to_count_and_coverage() {
        let mut s = ChaosSchedule { events: vec![], horizon_ms: 9_000 };
        s.ensure_floor(6, &KillThresholds::default());
        assert!(s.events.len() >= 6);
        assert_eq!(s.kinds_covered(), 3, "all three kinds represented");
        assert!(s.events.windows(2).all(|w| w[0].at_ms <= w[1].at_ms));
        assert!(s.events.iter().all(|e| e.at_ms >= 1
            && e.at_ms <= s.horizon_ms));
        // Already-rich schedules are left alone.
        let before = s.events.len();
        s.ensure_floor(3, &KillThresholds::default());
        assert_eq!(s.events.len(), before);
    }

    #[test]
    fn min_gap_rate_limits_each_kind() {
        let params = OuParams {
            // Start pinned far above every threshold with no noise: the
            // price stays up a while, so only the gap limits kills.
            mean: 5.0,
            reversion: 0.0,
            sigma: 0.0,
            dt_ms: 100,
            start: 5.0,
        };
        let thr = KillThresholds { worker: 1.0, unit: 1.0, stage: 1.0 };
        let s = ChaosSchedule::generate(3, 1_000, &params, &thr, 400);
        for kind in ProcessKind::ALL {
            let times: Vec<u64> = s
                .events
                .iter()
                .filter(|e| e.kind == kind)
                .map(|e| e.at_ms)
                .collect();
            assert!(!times.is_empty());
            for w in times.windows(2) {
                assert!(w[1] - w[0] >= 400, "gap violated: {times:?}");
            }
        }
    }
}
