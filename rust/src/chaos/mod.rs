//! Preemption-trace-driven chaos harness (`asyncflow chaos`).
//!
//! Spot-market preemption is the deployment reality the paper's
//! elastic/crash-safe machinery exists for: rollout workers, storage
//! units, and pipeline stages all die without warning and come back as
//! fresh processes. This module turns that into a repeatable test:
//!
//! * [`trace`] — an Ornstein–Uhlenbeck spot-price process mapped
//!   through per-process-kind preemption thresholds to a deterministic
//!   (seeded) schedule of SIGKILL events.
//! * [`supervisor`] — launches a full multi-process run (coordinator +
//!   re-exec'd workers/units/stages), executes the schedule, respawns
//!   replacements after a configurable delay, and measures recovery.
//! * [`invariants`] — pure checkers the supervisor polls between
//!   events: lease conservation (`granted = done + acked + requeued +
//!   in-flight`, from the `lease_*_rows` books in `stats`),
//!   exactly-once row accounting, weight-version convergence after
//!   each publish, and a throughput floor against the undisturbed
//!   warmup window. Violations are structured reports naming the
//!   invariant, the preceding kill event, and the offending
//!   task/lease/subscriber.
//!
//! See DESIGN.md §Chaos harness for the event schedule format, the
//! invariant definitions, and the supervisor lifecycle.

pub mod invariants;
pub mod supervisor;
pub mod trace;

pub use invariants::{
    check_lease_conservation, check_throughput_floor,
    check_weight_convergence, ExactlyOnceLedger, InvariantConfig,
    Violation, INV_EXACTLY_ONCE, INV_LEASE_CONSERVATION,
    INV_THROUGHPUT_FLOOR, INV_WEIGHT_CONVERGENCE,
};
pub use supervisor::{run_chaos, ChaosOptions, ChaosReport, KillRecord};
pub use trace::{
    ChaosEvent, ChaosSchedule, KillThresholds, OuParams, OuProcess,
    ProcessKind,
};
