//! The chaos supervisor: launch a full multi-process run, execute a
//! preemption schedule with SIGKILL, respawn replacements, and check
//! the invariants live between events.
//!
//! Topology (all child processes are re-exec'd `asyncflow`
//! subcommands, the same pattern `examples/mixed_fleet.rs` uses):
//!
//! ```text
//!   harness process                      children (SIGKILL targets)
//!   ───────────────                      ──────────────────────────
//!   Session + TcpJsonlServer   ◄──TCP──  rollout-worker --mock --relay ×W
//!   feeder thread (prompts)    ◄──TCP──  stage --stage reward --relay ×S
//!   trainer thread (leased     ◄──TCP──  storage-unit --slot i       ×U
//!     get_batch + ack)
//!   publisher thread (weight
//!     publishes every tick)
//! ```
//!
//! Clients run in `--relay` mode so every payload is replicated on the
//! coordinator: killing a storage unit degrades the run (slot falls
//! back to the local replica, re-attaches on respawn) without stranding
//! rows — which is exactly the availability story the chaos run is
//! asserting. The supervisor polls `stats` between events and feeds the
//! pure checkers in [`super::invariants`]; violations carry the label
//! of the preceding kill event.

use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::data::MathTaskGen;
use crate::runtime::{HostTensor, ParamSet};
use crate::service::{
    ConsumerSpec, GetBatchSpec, PutRow, ServiceClient, Session,
    SessionSpec, TcpJsonlServer,
};
use crate::transfer_queue::{Column, TaskSpec, Value};
use crate::util::json::Json;

use super::invariants::{
    check_lease_conservation, check_throughput_floor,
    check_weight_convergence, ExactlyOnceLedger, InvariantConfig,
    Violation,
};
use super::trace::{
    ChaosSchedule, KillThresholds, OuParams, ProcessKind,
};

/// Everything a chaos run is parameterized by. `exe` is the
/// `asyncflow` binary to re-exec for children (`current_exe()` from
/// the CLI, `env!("CARGO_BIN_EXE_asyncflow")` from integration tests).
#[derive(Debug, Clone)]
pub struct ChaosOptions {
    pub exe: PathBuf,
    pub seed: u64,
    /// Rollout-worker population target.
    pub workers: usize,
    /// Storage-unit processes (session gets the same number of slots).
    pub units: usize,
    /// Reward-stage processes.
    pub stages: usize,
    /// Undisturbed window before the first kill — the throughput
    /// baseline is measured over its second half.
    pub warmup_ms: u64,
    /// Chaos window the schedule spans.
    pub horizon_ms: u64,
    /// Max settle time after the last event for every fed row to train.
    pub drain_ms: u64,
    /// Kill → replacement spawn delay.
    pub respawn_delay_ms: u64,
    /// Invariant poll / supervision tick cadence.
    pub poll_ms: u64,
    /// Worker lease TTL (crash-detection bound) and decode chunk size.
    pub ttl_ms: u64,
    pub chunk_tokens: usize,
    /// Weight publish cadence for the convergence invariant.
    pub publish_every_ms: u64,
    /// Minimum scheduled kills (schedule is padded to this, covering
    /// all three process kinds).
    pub min_events: usize,
    /// Per-kind rate limit between kills.
    pub min_gap_ms: u64,
    pub ou: OuParams,
    pub thresholds: KillThresholds,
    pub invariants: InvariantConfig,
    /// Explicit schedule override (tests); `None` generates one from
    /// the OU trace.
    pub schedule: Option<ChaosSchedule>,
    /// Recompute the worker population target from observed throughput
    /// via the planner (`planner::live`).
    pub elastic: bool,
    /// Suppress per-event progress lines.
    pub quiet: bool,
}

impl ChaosOptions {
    pub fn new(exe: PathBuf) -> Self {
        ChaosOptions {
            exe,
            seed: 7,
            workers: 2,
            units: 1,
            stages: 1,
            warmup_ms: 3_000,
            horizon_ms: 10_000,
            drain_ms: 20_000,
            respawn_delay_ms: 600,
            poll_ms: 150,
            ttl_ms: 900,
            chunk_tokens: 8,
            publish_every_ms: 1_200,
            min_events: 6,
            min_gap_ms: 900,
            ou: OuParams::default(),
            thresholds: KillThresholds::default(),
            invariants: InvariantConfig::default(),
            schedule: None,
            elastic: false,
            quiet: false,
        }
    }

    /// CI-sized preset: short windows, ≥8 scheduled kills across all
    /// three process kinds (so ≥6 execute even if a couple of events
    /// land while their whole population is still respawning).
    pub fn smoke(exe: PathBuf) -> Self {
        let mut o = ChaosOptions::new(exe);
        o.warmup_ms = 2_500;
        o.horizon_ms = 9_000;
        o.drain_ms = 25_000;
        o.min_events = 8;
        o
    }
}

/// One executed kill and how long its population took to recover.
#[derive(Debug, Clone)]
pub struct KillRecord {
    /// Event label (`kill-worker@1500ms`).
    pub event: String,
    pub kind: ProcessKind,
    /// Process name that received SIGKILL.
    pub victim: String,
    /// Kill → replacement observed serving. `None` = never recovered
    /// inside the run (itself surfaced by the drain checks).
    pub recovered_ms: Option<u64>,
}

/// The chaos run's verdict + the numbers behind it.
#[derive(Debug)]
pub struct ChaosReport {
    pub seed: u64,
    pub horizon_ms: u64,
    /// Kills actually executed (a scheduled event is skipped when its
    /// whole population is already down awaiting respawn).
    pub kills: Vec<KillRecord>,
    pub events_skipped: usize,
    pub violations: Vec<Violation>,
    pub rows_fed: usize,
    pub rows_trained: usize,
    pub weight_publishes: u64,
    pub baseline_sps: f64,
    pub disturbed_sps: f64,
    /// `disturbed / baseline` (0 when no baseline).
    pub floor_ratio: f64,
    /// Worker population target after the elastic recomputation
    /// (`None` when `elastic` was off).
    pub elastic_workers: Option<usize>,
}

impl ChaosReport {
    pub fn kills_of(&self, kind: ProcessKind) -> usize {
        self.kills.iter().filter(|k| k.kind == kind).count()
    }

    pub fn passed(&self) -> bool {
        self.violations.is_empty()
    }

    fn recovery_sorted(&self) -> Vec<u64> {
        let mut v: Vec<u64> =
            self.kills.iter().filter_map(|k| k.recovered_ms).collect();
        v.sort_unstable();
        v
    }

    pub fn recovery_p50_ms(&self) -> Option<u64> {
        let v = self.recovery_sorted();
        (!v.is_empty()).then(|| v[v.len() / 2])
    }

    pub fn recovery_p99_ms(&self) -> Option<u64> {
        let v = self.recovery_sorted();
        (!v.is_empty()).then(|| v[(v.len() * 99 / 100).min(v.len() - 1)])
    }

    /// The `BENCH_chaos.json` document CI schema-validates.
    pub fn to_json(&self) -> Json {
        let events = Json::obj(vec![
            ("executed", Json::Num(self.kills.len() as f64)),
            ("skipped", Json::Num(self.events_skipped as f64)),
            (
                "worker",
                Json::Num(self.kills_of(ProcessKind::Worker) as f64),
            ),
            ("unit", Json::Num(self.kills_of(ProcessKind::Unit) as f64)),
            (
                "stage",
                Json::Num(self.kills_of(ProcessKind::Stage) as f64),
            ),
        ]);
        let recovery = Json::obj(vec![
            (
                "count",
                Json::Num(self.recovery_sorted().len() as f64),
            ),
            (
                "p50_ms",
                self.recovery_p50_ms()
                    .map_or(Json::Null, |v| Json::Num(v as f64)),
            ),
            (
                "p99_ms",
                self.recovery_p99_ms()
                    .map_or(Json::Null, |v| Json::Num(v as f64)),
            ),
        ]);
        let throughput = Json::obj(vec![
            ("baseline_sps", Json::Num(self.baseline_sps)),
            ("disturbed_sps", Json::Num(self.disturbed_sps)),
            ("floor_ratio", Json::Num(self.floor_ratio)),
        ]);
        let violations = Json::Arr(
            self.violations
                .iter()
                .map(|v| {
                    Json::obj(vec![
                        ("invariant", Json::Str(v.invariant.into())),
                        (
                            "task",
                            v.task.clone().map_or(Json::Null, Json::Str),
                        ),
                        (
                            "subject",
                            v.subject
                                .clone()
                                .map_or(Json::Null, Json::Str),
                        ),
                        ("detail", Json::Str(v.detail.clone())),
                        (
                            "after_event",
                            v.after_event
                                .clone()
                                .map_or(Json::Null, Json::Str),
                        ),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![
            ("seed", Json::Num(self.seed as f64)),
            ("horizon_ms", Json::Num(self.horizon_ms as f64)),
            ("events", events),
            ("recovery", recovery),
            ("throughput", throughput),
            ("rows_fed", Json::Num(self.rows_fed as f64)),
            ("rows_trained", Json::Num(self.rows_trained as f64)),
            (
                "weight_publishes",
                Json::Num(self.weight_publishes as f64),
            ),
            ("violations", violations),
        ])
    }
}

/// One supervised child slot: a stable identity whose occupant process
/// changes across kill/respawn generations.
struct ProcSlot {
    kind: ProcessKind,
    /// Unit slot number, or worker/stage ordinal.
    index: usize,
    generation: usize,
    /// Current process name (worker/stage identity on the wire).
    name: String,
    child: Option<Child>,
    spawned_at: Instant,
    /// When to (re)spawn a replacement, if one is due.
    respawn_at: Option<Instant>,
    /// Index into the kill record vec awaiting a recovery timestamp.
    pending_recovery: Option<usize>,
    killed_at: Option<Instant>,
    spawn_attempts: usize,
}

impl ProcSlot {
    fn proc_name(kind: ProcessKind, index: usize, generation: usize) -> String {
        match kind {
            ProcessKind::Worker => format!("cw{index}.g{generation}"),
            ProcessKind::Unit => format!("unit{index}.g{generation}"),
            ProcessKind::Stage => format!("grader{index}.g{generation}"),
        }
    }
}

/// Child-process fleet with kill-on-drop: whatever path `run_chaos`
/// exits through, no orphan keeps running.
struct Fleet {
    exe: PathBuf,
    addr: String,
    ttl_ms: u64,
    chunk_tokens: usize,
    seed: u64,
    slots: Vec<ProcSlot>,
    rr: usize,
}

impl Fleet {
    fn new(exe: PathBuf, addr: String, opts: &ChaosOptions) -> Fleet {
        Fleet {
            exe,
            addr,
            ttl_ms: opts.ttl_ms,
            chunk_tokens: opts.chunk_tokens,
            seed: opts.seed,
            slots: Vec::new(),
            rr: 0,
        }
    }

    fn add(&mut self, kind: ProcessKind, index: usize) -> Result<()> {
        let mut slot = ProcSlot {
            kind,
            index,
            generation: 0,
            name: ProcSlot::proc_name(kind, index, 0),
            child: None,
            spawned_at: Instant::now(),
            respawn_at: None,
            pending_recovery: None,
            killed_at: None,
            spawn_attempts: 0,
        };
        self.spawn(&mut slot)?;
        self.slots.push(slot);
        Ok(())
    }

    fn spawn(&self, slot: &mut ProcSlot) -> Result<()> {
        spawn_child(
            &self.exe,
            &self.addr,
            self.ttl_ms,
            self.chunk_tokens,
            self.seed,
            slot,
        )
    }
}

/// Spawn the child for `slot` (free function so [`Fleet::tick`] can
/// respawn while holding a mutable borrow of its own slot list).
fn spawn_child(
    exe: &Path,
    addr: &str,
    ttl_ms: u64,
    chunk_tokens: usize,
    seed: u64,
    slot: &mut ProcSlot,
) -> Result<()> {
    let mut cmd = Command::new(exe);
    match slot.kind {
        ProcessKind::Worker => {
            cmd.args([
                "rollout-worker",
                "--connect",
                addr,
                "--mock",
                "--relay",
                "--name",
                &slot.name,
                "--ttl-ms",
                &ttl_ms.to_string(),
                "--chunk-tokens",
                &chunk_tokens.to_string(),
                "--seed",
                &(seed * 1000
                    + slot.index as u64 * 10
                    + slot.generation as u64)
                    .to_string(),
            ]);
        }
        ProcessKind::Unit => {
            cmd.args([
                "storage-unit",
                "--connect",
                addr,
                "--slot",
                &slot.index.to_string(),
                "--listen",
                "127.0.0.1:0",
            ]);
        }
        ProcessKind::Stage => {
            cmd.args([
                "stage",
                "--connect",
                addr,
                "--stage",
                "reward",
                "--relay",
                "--name",
                &slot.name,
                "--lease-ttl-ms",
                &ttl_ms.to_string(),
            ]);
        }
    }
    let child = cmd
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .stdin(Stdio::null())
        .spawn()
        .with_context(|| {
            format!("spawning {} ({})", slot.name, slot.kind.name())
        })?;
    slot.child = Some(child);
    slot.spawned_at = Instant::now();
    slot.respawn_at = None;
    slot.spawn_attempts += 1;
    Ok(())
}

impl Fleet {
    /// SIGKILL one live instance of `kind`, round-robin. Returns the
    /// victim's name, or `None` when the whole population is already
    /// down.
    fn kill_one(
        &mut self,
        kind: ProcessKind,
        respawn_delay: Duration,
        record_idx: usize,
    ) -> Option<String> {
        let n = self.slots.len();
        for probe in 0..n {
            let i = (self.rr + probe) % n;
            let alive = self.slots[i].kind == kind
                && matches!(
                    self.slots[i].child.as_mut().map(|c| c.try_wait()),
                    Some(Ok(None))
                );
            if !alive {
                continue;
            }
            self.rr = i + 1;
            let slot = &mut self.slots[i];
            if let Some(mut child) = slot.child.take() {
                let _ = child.kill();
                let _ = child.wait();
            }
            let victim = slot.name.clone();
            slot.generation += 1;
            slot.name =
                ProcSlot::proc_name(slot.kind, slot.index, slot.generation);
            slot.killed_at = Some(Instant::now());
            slot.respawn_at = Some(Instant::now() + respawn_delay);
            slot.pending_recovery = Some(record_idx);
            slot.spawn_attempts = 0;
            return Some(victim);
        }
        None
    }

    /// Supervision tick: respawn due slots, retry failed spawns (a
    /// respawned storage unit can lose the attach race against the
    /// coordinator's lazy detach of its dead predecessor and exit — it
    /// is retried until the slot frees up), and stamp recoveries.
    fn tick(
        &mut self,
        stats: Option<&crate::service::ServiceStats>,
        kills: &mut [KillRecord],
    ) {
        let now = Instant::now();
        for slot in &mut self.slots {
            // Reap and clear children that exited on their own.
            let exited = match slot.child.as_mut() {
                Some(c) => !matches!(c.try_wait(), Ok(None)),
                None => false,
            };
            if exited {
                if let Some(mut c) = slot.child.take() {
                    let _ = c.wait();
                }
                // Unexpected death (or a lost unit attach race):
                // schedule another spawn, bounded.
                if slot.respawn_at.is_none() && slot.spawn_attempts < 40 {
                    slot.respawn_at =
                        Some(now + Duration::from_millis(300));
                }
            }
            if let Some(at) = slot.respawn_at {
                if now >= at && slot.child.is_none() {
                    let _ = spawn_child(
                        &self.exe,
                        &self.addr,
                        self.ttl_ms,
                        self.chunk_tokens,
                        self.seed,
                        slot,
                    );
                }
            }
            // Recovery: the replacement is observed serving.
            if let (Some(rec), Some(t0)) =
                (slot.pending_recovery, slot.killed_at)
            {
                let alive = matches!(
                    slot.child.as_mut().map(|c| c.try_wait()),
                    Some(Ok(None))
                );
                let recovered = alive
                    && match slot.kind {
                        ProcessKind::Unit => stats.is_some_and(|s| {
                            s.units.iter().any(|u| {
                                u.unit == slot.index
                                    && u.endpoint.is_some()
                            })
                        }),
                        ProcessKind::Worker => stats.is_some_and(|s| {
                            s.weights.as_ref().is_some_and(|w| {
                                w.subscribers
                                    .iter()
                                    .any(|sub| sub.id == slot.name)
                            })
                        }),
                        // Stages carry no server-side identity in
                        // `stats`; serving = replacement alive past one
                        // tick.
                        ProcessKind::Stage => true,
                    };
                if recovered {
                    kills[rec].recovered_ms =
                        Some(t0.elapsed().as_millis() as u64);
                    slot.pending_recovery = None;
                    slot.killed_at = None;
                }
            }
        }
    }

    /// Names of workers alive and past `grace` (stable enough to judge
    /// their weight-subscriber lag).
    fn settled_workers(&mut self, grace: Duration) -> Vec<String> {
        let now = Instant::now();
        self.slots
            .iter_mut()
            .filter(|s| s.kind == ProcessKind::Worker)
            .filter(|s| {
                matches!(
                    s.child.as_mut().map(|c| c.try_wait()),
                    Some(Ok(None))
                ) && now.duration_since(s.spawned_at) >= grace
            })
            .map(|s| s.name.clone())
            .collect()
    }

    fn population(&self, kind: ProcessKind) -> usize {
        self.slots.iter().filter(|s| s.kind == kind).count()
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        for slot in &mut self.slots {
            if let Some(mut c) = slot.child.take() {
                let _ = c.kill();
                let _ = c.wait();
            }
        }
    }
}

/// Violation sink shared with the trainer thread, deduplicated by
/// (invariant, task, subject) so a persistent imbalance reports once
/// instead of once per poll.
#[derive(Default)]
struct ViolationSink {
    seen: HashSet<String>,
    out: Vec<Violation>,
}

impl ViolationSink {
    fn push(&mut self, v: Violation) {
        let key = format!(
            "{}|{}|{}",
            v.invariant,
            v.task.as_deref().unwrap_or(""),
            v.subject.as_deref().unwrap_or("")
        );
        if self.seen.insert(key) {
            self.out.push(v);
        }
    }

    fn extend(&mut self, vs: Vec<Violation>) {
        for v in vs {
            self.push(v);
        }
    }
}

/// Run the full chaos harness: bring up the topology, execute the
/// schedule, check invariants live, drain, and report.
pub fn run_chaos(opts: &ChaosOptions) -> Result<ChaosReport> {
    let schedule = match &opts.schedule {
        // An explicit schedule (tests) runs exactly as given.
        Some(s) => s.clone(),
        None => {
            let mut s = ChaosSchedule::generate(
                opts.seed,
                opts.horizon_ms,
                &opts.ou,
                &opts.thresholds,
                opts.min_gap_ms,
            );
            s.ensure_floor(opts.min_events, &opts.thresholds);
            s
        }
    };

    // ── Coordinator: in-proc session + TCP server for the children.
    let spec = SessionSpec {
        storage_units: opts.units.max(1),
        tasks: vec![
            TaskSpec::new("rollout", vec![Column::Prompts]),
            TaskSpec::new("reward", vec![Column::Responses]),
            TaskSpec::new(
                "train",
                vec![Column::Responses, Column::OldLogp, Column::Rewards],
            ),
        ],
    };
    let initial = ParamSet::new(
        0,
        vec![HostTensor::from_f32(vec![4], &[0.0, 0.0, 0.0, 0.0])?],
    );
    let session = Arc::new(Session::init_engines(spec, initial)?);
    let server = TcpJsonlServer::bind(session.clone(), ("127.0.0.1", 0))?;
    let addr = format!("127.0.0.1:{}", server.port());
    let client = ServiceClient::in_proc(session.clone());

    let stop = Arc::new(AtomicBool::new(false));
    let stop_feed = Arc::new(AtomicBool::new(false));
    let fed = Arc::new(AtomicUsize::new(0));
    let ledger = Arc::new(Mutex::new(ExactlyOnceLedger::new()));
    let trainer_violations: Arc<Mutex<Vec<Violation>>> =
        Arc::new(Mutex::new(Vec::new()));
    let last_publish: Arc<Mutex<Option<Instant>>> =
        Arc::new(Mutex::new(None));
    let publishes = Arc::new(AtomicU64::new(0));

    // ── Feeder: keep the rollout queue shallow-but-never-empty so
    // throughput is steady across the whole run.
    let feeder = {
        let session = session.clone();
        let stop_feed = stop_feed.clone();
        let fed = fed.clone();
        let seed = opts.seed;
        std::thread::spawn(move || {
            let client = ServiceClient::in_proc(session);
            let mut gen = MathTaskGen::new(seed ^ 0xfeed, 16);
            while !stop_feed.load(Ordering::Relaxed) {
                let ready = client
                    .stats()
                    .ok()
                    .and_then(|s| {
                        s.tasks
                            .iter()
                            .find(|t| t.name == "rollout")
                            .map(|t| t.ready)
                    })
                    .unwrap_or(usize::MAX);
                if ready < 24 {
                    let rows: Vec<PutRow> = (0..12)
                        .map(|_| {
                            let task = gen.next_task();
                            PutRow::new(vec![
                                (
                                    Column::Prompts,
                                    Value::I32s(task.prompt_tokens),
                                ),
                                (
                                    Column::Custom("answer".into()),
                                    Value::Text(task.answer.to_string()),
                                ),
                            ])
                        })
                        .collect();
                    let n = rows.len();
                    if client.put_batch(rows).is_ok() {
                        fed.fetch_add(n, Ordering::Relaxed);
                    }
                }
                std::thread::sleep(Duration::from_millis(30));
            }
        })
    };

    // ── Trainer: leased consumption + ack, the exactly-once witness.
    let trainer = {
        let session = session.clone();
        let stop = stop.clone();
        let ledger = ledger.clone();
        let sink = trainer_violations.clone();
        let ttl = (opts.ttl_ms * 2).max(1_000);
        std::thread::spawn(move || {
            let client = ServiceClient::in_proc(session);
            let spec = GetBatchSpec {
                task: "train".into(),
                group: 0,
                columns: vec![Column::Responses, Column::Rewards],
                count: 8,
                min: 1,
                timeout_ms: 100,
                consumer: Some(ConsumerSpec {
                    id: "chaos-trainer".into(),
                    ttl_ms: ttl,
                }),
            };
            loop {
                match client.get_batch_leased_blocking_until(&spec, || {
                    stop.load(Ordering::Relaxed)
                }) {
                    Ok(Some(lb)) => {
                        let indices = lb.batch.indices.clone();
                        if lb.ack().is_ok() {
                            let vs = ledger
                                .lock()
                                .unwrap()
                                .observe(&indices, None);
                            if !vs.is_empty() {
                                sink.lock().unwrap().extend(vs);
                            }
                        }
                        // An ack error means the lease TTL lapsed and
                        // the rows requeued — they will be served
                        // again, and counting them now would fake a
                        // duplicate.
                    }
                    Ok(None) => break, // aborted or stream closed
                    Err(_) => {
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        std::thread::sleep(Duration::from_millis(50));
                    }
                }
            }
        })
    };

    // ── Publisher: version ticks for the convergence invariant.
    let publisher = {
        let session = session.clone();
        let stop = stop.clone();
        let last_publish = last_publish.clone();
        let publishes = publishes.clone();
        let every = Duration::from_millis(opts.publish_every_ms.max(100));
        std::thread::spawn(move || {
            let client = ServiceClient::in_proc(session);
            let mut version = 0u64;
            let mut next = Instant::now() + every;
            while !stop.load(Ordering::Relaxed) {
                if Instant::now() >= next {
                    version += 1;
                    let v = version as f32;
                    let tensor = HostTensor::from_f32(
                        vec![4],
                        &[v, -v, v * 0.5, 1.0],
                    )
                    .expect("static shape");
                    if client
                        .weight_sync_notify(ParamSet::new(
                            version,
                            vec![tensor],
                        ))
                        .is_ok()
                    {
                        *last_publish.lock().unwrap() =
                            Some(Instant::now());
                        publishes.fetch_add(1, Ordering::Relaxed);
                    }
                    next = Instant::now() + every;
                }
                std::thread::sleep(Duration::from_millis(25));
            }
        })
    };

    // ── Children.
    let mut fleet = Fleet::new(opts.exe.clone(), addr.clone(), opts);
    for i in 0..opts.workers.max(1) {
        fleet.add(ProcessKind::Worker, i)?;
    }
    for i in 0..opts.units.max(1) {
        fleet.add(ProcessKind::Unit, i)?;
    }
    for i in 0..opts.stages.max(1) {
        fleet.add(ProcessKind::Stage, i)?;
    }

    let mut sink = ViolationSink::default();
    let mut kills: Vec<KillRecord> = Vec::new();
    let mut skipped = 0usize;
    let grace = Duration::from_millis(opts.invariants.convergence_grace_ms);
    let poll = Duration::from_millis(opts.poll_ms.max(20));
    let mut last_event_label: Option<String> = None;

    // One supervision tick: respawns, recoveries, live invariants.
    let tick = |fleet: &mut Fleet,
                kills: &mut Vec<KillRecord>,
                sink: &mut ViolationSink,
                last_event: Option<&str>| {
        let stats = client.stats().ok();
        fleet.tick(stats.as_ref(), kills);
        if let Some(s) = &stats {
            sink.extend(check_lease_conservation(s, last_event));
            if let Some(w) = &s.weights {
                let since = last_publish
                    .lock()
                    .unwrap()
                    .map(|t| t.elapsed().as_millis() as u64);
                if let Some(ms) = since {
                    let live = fleet.settled_workers(grace);
                    sink.extend(check_weight_convergence(
                        w,
                        &live,
                        ms,
                        &opts.invariants,
                        last_event,
                    ));
                }
            }
        }
        sink.extend(std::mem::take(
            &mut *trainer_violations.lock().unwrap(),
        ));
    };

    // ── Warmup: undisturbed baseline over the window's second half.
    let half = Duration::from_millis(opts.warmup_ms / 2);
    let warm_deadline = Instant::now() + half;
    while Instant::now() < warm_deadline {
        tick(&mut fleet, &mut kills, &mut sink, None);
        std::thread::sleep(poll);
    }
    let base_t0 = Instant::now();
    let base_n0 = ledger.lock().unwrap().count();
    let warm_deadline = Instant::now() + half;
    while Instant::now() < warm_deadline {
        tick(&mut fleet, &mut kills, &mut sink, None);
        std::thread::sleep(poll);
    }
    let baseline_sps = (ledger.lock().unwrap().count() - base_n0) as f64
        / base_t0.elapsed().as_secs_f64();

    // ── Elastic population: wire the planner to observed throughput.
    let mut elastic_workers = None;
    if opts.elastic {
        let cfg = crate::config::RlConfig {
            chunk_tokens: opts.chunk_tokens,
            lease_ttl_ms: opts.ttl_ms,
            rollout_workers: opts.workers,
            ..crate::config::RlConfig::default()
        };
        let target = crate::planner::live::recommend_workers(
            &cfg,
            baseline_sps,
            fleet.population(ProcessKind::Worker),
        );
        let have = fleet.population(ProcessKind::Worker);
        for i in have..target.min(have + 2) {
            fleet.add(ProcessKind::Worker, i)?;
        }
        elastic_workers = Some(target);
        if !opts.quiet {
            crate::log_info!(
                "chaos",
                "elastic: planner recommends {target} workers \
                 (observed {baseline_sps:.1} samples/s, running {have})"
            );
        }
    }

    // ── Chaos phase: execute the schedule.
    let chaos_t0 = Instant::now();
    let chaos_n0 = ledger.lock().unwrap().count();
    for ev in schedule.events.clone() {
        let due = chaos_t0 + Duration::from_millis(ev.at_ms);
        while Instant::now() < due {
            tick(
                &mut fleet,
                &mut kills,
                &mut sink,
                last_event_label.as_deref(),
            );
            let now = Instant::now();
            if due > now {
                std::thread::sleep(poll.min(due - now));
            }
        }
        let label = ev.label();
        let record_idx = kills.len();
        kills.push(KillRecord {
            event: label.clone(),
            kind: ev.kind,
            victim: String::new(),
            recovered_ms: None,
        });
        match fleet.kill_one(
            ev.kind,
            Duration::from_millis(opts.respawn_delay_ms),
            record_idx,
        ) {
            Some(victim) => {
                if !opts.quiet {
                    crate::log_info!(
                        "chaos",
                        "{label}: SIGKILL {victim} (spot price {:.2})",
                        ev.price
                    );
                }
                kills[record_idx].victim = victim;
                last_event_label = Some(label);
            }
            None => {
                kills.pop();
                skipped += 1;
            }
        }
    }
    let horizon_deadline =
        chaos_t0 + Duration::from_millis(schedule.horizon_ms);
    while Instant::now() < horizon_deadline {
        tick(
            &mut fleet,
            &mut kills,
            &mut sink,
            last_event_label.as_deref(),
        );
        std::thread::sleep(poll);
    }
    let disturbed_sps = (ledger.lock().unwrap().count() - chaos_n0)
        as f64
        / chaos_t0.elapsed().as_secs_f64();

    // ── Drain: stop feeding, let every fed row reach the trainer.
    stop_feed.store(true, Ordering::Relaxed);
    let _ = feeder.join();
    let rows_fed = fed.load(Ordering::Relaxed);
    let drain_deadline =
        Instant::now() + Duration::from_millis(opts.drain_ms);
    while Instant::now() < drain_deadline {
        if ledger.lock().unwrap().count() >= rows_fed {
            break;
        }
        tick(
            &mut fleet,
            &mut kills,
            &mut sink,
            last_event_label.as_deref(),
        );
        std::thread::sleep(poll);
    }
    // Final books, after the graph settled.
    tick(
        &mut fleet,
        &mut kills,
        &mut sink,
        last_event_label.as_deref(),
    );
    let rows_trained = ledger.lock().unwrap().count();
    sink.extend(ledger.lock().unwrap().check_complete(rows_fed));
    sink.extend(check_throughput_floor(
        baseline_sps,
        disturbed_sps,
        &opts.invariants,
    ));

    // ── Teardown: children die with the Fleet drop; helper threads
    // stop on the flag.
    stop.store(true, Ordering::Relaxed);
    let _ = trainer.join();
    let _ = publisher.join();
    drop(fleet);
    let _ = client.shutdown();

    let floor_ratio = if baseline_sps > 0.0 {
        disturbed_sps / baseline_sps
    } else {
        0.0
    };
    Ok(ChaosReport {
        seed: opts.seed,
        horizon_ms: schedule.horizon_ms,
        kills,
        events_skipped: skipped,
        violations: sink.out,
        rows_fed,
        rows_trained,
        weight_publishes: publishes.load(Ordering::Relaxed),
        baseline_sps,
        disturbed_sps,
        floor_ratio,
        elastic_workers,
    })
}
