//! Live invariant checking for chaos runs.
//!
//! Every check is a pure function over snapshot data (the `stats` verb
//! reply, the trainer's consumption ledger, plain numbers), so the
//! supervisor can poll them between kill events and tests can feed
//! hand-built snapshots that *must* trip each invariant (mutation-style
//! negative tests — see below).
//!
//! The four invariants, from DESIGN.md §Chaos harness:
//!
//! 1. **Lease conservation** — per task, every row ever granted under a
//!    lease is exactly one of: done (committed), acked, requeued, or
//!    still in flight. `granted = done + acked + requeued + in_flight`,
//!    checked from the `lease_*_rows` books the coordinator maintains
//!    under its registry locks.
//! 2. **Exactly-once consumption** — the trainer's acked rows never
//!    contain a duplicate global index, and after the drain every fed
//!    row has been trained exactly once.
//! 3. **Weight convergence** — bounded time after a publish, every
//!    *live* subscriber has caught up to within `max_weight_lag`
//!    versions. (Dead subscribers keep their last reported version in
//!    the ledger forever; the supervisor passes the live set.)
//! 4. **Throughput floor** — the disturbed run sustains at least
//!    `throughput_floor` of the undisturbed warmup window's samples/s.

use std::collections::HashSet;
use std::fmt;

use crate::service::ServiceStats;
use crate::transfer_queue::GlobalIndex;
use crate::weights::WeightPlaneStats;

/// Invariant identifiers, used verbatim in reports and CI gating.
pub const INV_LEASE_CONSERVATION: &str = "lease-conservation";
pub const INV_EXACTLY_ONCE: &str = "exactly-once";
pub const INV_WEIGHT_CONVERGENCE: &str = "weight-convergence";
pub const INV_THROUGHPUT_FLOOR: &str = "throughput-floor";

/// Tunables for the checker.
#[derive(Debug, Clone)]
pub struct InvariantConfig {
    /// Max acceptable `published_version - subscriber_version` for a
    /// live subscriber once the grace window has passed.
    pub max_weight_lag: u64,
    /// Time after a publish (or a subscriber spawn) during which lag is
    /// not judged — distribution is asynchronous by design.
    pub convergence_grace_ms: u64,
    /// Disturbed-over-undisturbed samples/s ratio that must survive.
    pub throughput_floor: f64,
}

impl Default for InvariantConfig {
    fn default() -> Self {
        InvariantConfig {
            max_weight_lag: 1,
            convergence_grace_ms: 3_000,
            throughput_floor: 0.5,
        }
    }
}

/// One tripped invariant: which law, where, and what the books said.
#[derive(Debug, Clone)]
pub struct Violation {
    /// One of the `INV_*` identifiers.
    pub invariant: &'static str,
    /// Task the violation is scoped to, when per-task.
    pub task: Option<String>,
    /// Offending lease owner / subscriber / row, when identifiable.
    pub subject: Option<String>,
    /// Human-readable account of the broken equation.
    pub detail: String,
    /// Label of the chaos event that preceded the failing check
    /// ([`super::trace::ChaosEvent::label`]), when inside a chaos run.
    pub after_event: Option<String>,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}]", self.invariant)?;
        if let Some(t) = &self.task {
            write!(f, " task={t}")?;
        }
        if let Some(s) = &self.subject {
            write!(f, " subject={s}")?;
        }
        write!(f, ": {}", self.detail)?;
        if let Some(e) = &self.after_event {
            write!(f, " (after {e})")?;
        }
        Ok(())
    }
}

/// Lease conservation: for every task with lease traffic,
/// `granted = done + acked + requeued + leased`. The four books and the
/// in-flight gauge all come from one `stats` reply, whose per-registry
/// snapshot is taken under the registry lock — an imbalance is a real
/// leak (or double count), not a race.
pub fn check_lease_conservation(
    stats: &ServiceStats,
    after_event: Option<&str>,
) -> Vec<Violation> {
    let mut out = Vec::new();
    for t in &stats.tasks {
        if t.lease_granted_rows == 0 {
            continue;
        }
        let accounted = t.lease_done_rows
            + t.lease_acked_rows
            + t.lease_requeued_rows
            + t.leased as u64;
        if accounted != t.lease_granted_rows {
            out.push(Violation {
                invariant: INV_LEASE_CONSERVATION,
                task: Some(t.name.clone()),
                subject: None,
                detail: format!(
                    "granted {} != done {} + acked {} + requeued {} + \
                     in-flight {} (= {})",
                    t.lease_granted_rows,
                    t.lease_done_rows,
                    t.lease_acked_rows,
                    t.lease_requeued_rows,
                    t.leased,
                    accounted
                ),
                after_event: after_event.map(str::to_string),
            });
        }
    }
    out
}

/// Exactly-once ledger the trainer feeds as it acks batches. Duplicate
/// observations trip immediately; [`ExactlyOnceLedger::check_complete`]
/// closes the books at drain time.
#[derive(Debug, Default)]
pub struct ExactlyOnceLedger {
    seen: HashSet<u64>,
}

impl ExactlyOnceLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Rows trained so far (unique).
    pub fn count(&self) -> usize {
        self.seen.len()
    }

    /// Record a trained (served-and-acked) batch; a global index seen
    /// twice is a double-trained row.
    pub fn observe(
        &mut self,
        indices: &[GlobalIndex],
        after_event: Option<&str>,
    ) -> Vec<Violation> {
        let mut out = Vec::new();
        for idx in indices {
            if !self.seen.insert(idx.0) {
                out.push(Violation {
                    invariant: INV_EXACTLY_ONCE,
                    task: None,
                    subject: Some(format!("row {}", idx.0)),
                    detail: format!(
                        "global index {} trained twice",
                        idx.0
                    ),
                    after_event: after_event.map(str::to_string),
                });
            }
        }
        out
    }

    /// Drain-time closure: every fed row must have been trained.
    pub fn check_complete(&self, rows_fed: usize) -> Vec<Violation> {
        if self.seen.len() >= rows_fed {
            return Vec::new();
        }
        vec![Violation {
            invariant: INV_EXACTLY_ONCE,
            task: None,
            subject: None,
            detail: format!(
                "{} of {} fed rows trained — {} rows lost",
                self.seen.len(),
                rows_fed,
                rows_fed - self.seen.len()
            ),
            after_event: None,
        }]
    }
}

/// Weight convergence: once `convergence_grace_ms` has passed since the
/// last publish, every live subscriber must be within `max_weight_lag`
/// versions of the published snapshot. `live` is the supervisor's list
/// of subscriber ids currently running (killed processes legitimately
/// freeze in the ledger and are skipped).
pub fn check_weight_convergence(
    weights: &WeightPlaneStats,
    live: &[String],
    ms_since_publish: u64,
    cfg: &InvariantConfig,
    after_event: Option<&str>,
) -> Vec<Violation> {
    if weights.published_version == 0
        || ms_since_publish < cfg.convergence_grace_ms
    {
        return Vec::new();
    }
    let mut out = Vec::new();
    for sub in &weights.subscribers {
        if !live.iter().any(|l| l == &sub.id) {
            continue;
        }
        let lag = weights.published_version.saturating_sub(sub.version);
        if lag > cfg.max_weight_lag {
            out.push(Violation {
                invariant: INV_WEIGHT_CONVERGENCE,
                task: None,
                subject: Some(sub.id.clone()),
                detail: format!(
                    "subscriber stuck at v{} while v{} published \
                     {}ms ago (lag {} > {})",
                    sub.version,
                    weights.published_version,
                    ms_since_publish,
                    lag,
                    cfg.max_weight_lag
                ),
                after_event: after_event.map(str::to_string),
            });
        }
    }
    out
}

/// Throughput floor: disturbed samples/s must hold `throughput_floor`
/// of the undisturbed baseline. A non-positive baseline means the
/// warmup produced nothing to compare against — reported as its own
/// violation rather than silently passing.
pub fn check_throughput_floor(
    baseline_sps: f64,
    disturbed_sps: f64,
    cfg: &InvariantConfig,
) -> Vec<Violation> {
    if baseline_sps <= 0.0 {
        return vec![Violation {
            invariant: INV_THROUGHPUT_FLOOR,
            task: None,
            subject: None,
            detail: "undisturbed warmup produced no samples — no \
                     baseline to hold the floor against"
                .into(),
            after_event: None,
        }];
    }
    let ratio = disturbed_sps / baseline_sps;
    if ratio < cfg.throughput_floor {
        return vec![Violation {
            invariant: INV_THROUGHPUT_FLOOR,
            task: None,
            subject: None,
            detail: format!(
                "disturbed {disturbed_sps:.2} samples/s is {:.0}% of \
                 baseline {baseline_sps:.2} (floor {:.0}%)",
                ratio * 100.0,
                cfg.throughput_floor * 100.0
            ),
            after_event: None,
        }];
    }
    Vec::new()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::{ServiceStats, TaskStats};
    use crate::weights::SubscriberLag;

    fn task(name: &str) -> TaskStats {
        TaskStats {
            name: name.into(),
            ready: 0,
            consumed: 0,
            policy: "fcfs".into(),
            leased: 0,
            waiting_consumers: 0,
            oldest_ready_age_ms: None,
            lease_granted_rows: 0,
            lease_done_rows: 0,
            lease_acked_rows: 0,
            lease_requeued_rows: 0,
        }
    }

    fn stats(tasks: Vec<TaskStats>) -> ServiceStats {
        ServiceStats {
            tasks,
            units: vec![],
            resident_rows: 0,
            param_version: 0,
            closed: false,
            weights: None,
            control: None,
            fleet: None,
        }
    }

    // Mutation-style negative tests: each hand-built snapshot carries
    // one seeded defect, and the matching invariant (and only it) must
    // trip.

    #[test]
    fn leaked_lease_trips_conservation() {
        let mut t = task("rollout");
        // 10 granted, but the books only account for 8: a lease was
        // dropped without ack/revoke/requeue — the exact bug sweep and
        // revoke paths exist to prevent.
        t.lease_granted_rows = 10;
        t.lease_done_rows = 4;
        t.lease_acked_rows = 2;
        t.lease_requeued_rows = 1;
        t.leased = 1;
        let v =
            check_lease_conservation(&stats(vec![t]), Some("kill-worker@500ms"));
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].invariant, INV_LEASE_CONSERVATION);
        assert_eq!(v[0].task.as_deref(), Some("rollout"));
        assert_eq!(v[0].after_event.as_deref(), Some("kill-worker@500ms"));
        assert!(v[0].detail.contains("granted 10"));
    }

    #[test]
    fn balanced_books_and_idle_tasks_pass() {
        let mut busy = task("train");
        busy.lease_granted_rows = 12;
        busy.lease_done_rows = 6;
        busy.lease_acked_rows = 3;
        busy.lease_requeued_rows = 1;
        busy.leased = 2;
        // Idle task (all zeros, e.g. decoded from an old peer) is not
        // judged.
        let idle = task("reward");
        assert!(check_lease_conservation(&stats(vec![busy, idle]), None)
            .is_empty());
    }

    #[test]
    fn double_trained_row_trips_exactly_once() {
        let mut ledger = ExactlyOnceLedger::new();
        let first = ledger.observe(
            &[GlobalIndex(3), GlobalIndex(4)],
            None,
        );
        assert!(first.is_empty());
        let dup = ledger.observe(&[GlobalIndex(4)], Some("kill-stage@2000ms"));
        assert_eq!(dup.len(), 1);
        assert_eq!(dup[0].invariant, INV_EXACTLY_ONCE);
        assert!(dup[0].detail.contains("index 4"));
        assert_eq!(dup[0].after_event.as_deref(), Some("kill-stage@2000ms"));
        assert_eq!(ledger.count(), 2);
    }

    #[test]
    fn lost_rows_trip_completion_check() {
        let mut ledger = ExactlyOnceLedger::new();
        ledger.observe(&[GlobalIndex(0), GlobalIndex(1)], None);
        assert!(ledger.check_complete(2).is_empty());
        let v = ledger.check_complete(5);
        assert_eq!(v.len(), 1);
        assert!(v[0].detail.contains("3 rows lost"), "{}", v[0].detail);
    }

    #[test]
    fn stuck_subscriber_trips_convergence() {
        let weights = WeightPlaneStats {
            published_version: 7,
            tensors: 2,
            subscribers: vec![
                SubscriberLag { id: "w0".into(), version: 7 },
                SubscriberLag { id: "w1".into(), version: 2 },
                // Dead worker frozen at an ancient version: skipped
                // because the supervisor says it is not live.
                SubscriberLag { id: "w-dead".into(), version: 0 },
            ],
            ..WeightPlaneStats::default()
        };
        let live = vec!["w0".to_string(), "w1".to_string()];
        let cfg = InvariantConfig::default();
        let v = check_weight_convergence(&weights, &live, 5_000, &cfg, None);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].invariant, INV_WEIGHT_CONVERGENCE);
        assert_eq!(v[0].subject.as_deref(), Some("w1"));
        // Inside the grace window nothing is judged.
        assert!(
            check_weight_convergence(&weights, &live, 100, &cfg, None)
                .is_empty()
        );
    }

    #[test]
    fn throughput_floor_judges_ratio() {
        let cfg = InvariantConfig::default();
        assert!(check_throughput_floor(10.0, 6.0, &cfg).is_empty());
        let v = check_throughput_floor(10.0, 3.0, &cfg);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].invariant, INV_THROUGHPUT_FLOOR);
        // No baseline is itself a failure, not a silent pass.
        assert_eq!(check_throughput_floor(0.0, 5.0, &cfg).len(), 1);
    }

    #[test]
    fn violation_display_names_everything() {
        let v = Violation {
            invariant: INV_LEASE_CONSERVATION,
            task: Some("rollout".into()),
            subject: Some("lease 9".into()),
            detail: "granted 3 != accounted 2".into(),
            after_event: Some("kill-unit@750ms".into()),
        };
        let s = v.to_string();
        assert!(s.contains("lease-conservation"));
        assert!(s.contains("task=rollout"));
        assert!(s.contains("lease 9"));
        assert!(s.contains("after kill-unit@750ms"));
    }
}
