//! Coordinator-side rollout manager: leases prompt groups to an elastic
//! pool of workers and streams their partial generations into the
//! TransferQueue.
//!
//! The manager sits between the service dispatcher and the queue:
//!
//! ```text
//!  lease_prompts ─▶ task controller (exactly-once pop, long-poll) ─▶ Lease
//!  put_chunk     ─▶ LeaseTable partial-row state ─┬─(row finished)──▶
//!                                                 └▶ Responses+OldLogp
//!  (lease expires) ─▶ Controller::unconsume ─▶ next lease_prompts
//! ```
//!
//! Load balancing is pull-based (the paper's §3.3 dynamic view): a worker
//! asks for work exactly when it has capacity, so requeued rows land on
//! the least-loaded peer — the one polling — without any push-side
//! placement logic. Expiry is detected lazily: every verb sweeps the
//! lease table first, so a crashed worker's rows reappear as soon as any
//! peer asks for more work (bounded by the peers' long-poll timeout).
//! Downstream stages that key on `Responses` (reference, reward) unlock
//! per row the moment that row's final chunk lands, while the long tail
//! of its group is still decoding — the streaming-overlap claim made
//! concrete.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::transfer_queue::{
    Batch, Column, GlobalIndex, RequestOutcome, TransferQueue, Value,
};

use super::lease::{LeaseId, LeaseTable, WorkerStat};

/// One row's increment in a `put_chunk` request.
#[derive(Debug, Clone, PartialEq)]
pub struct ChunkRow {
    pub index: GlobalIndex,
    /// Response tokens decoded since the last chunk (may be empty when
    /// only flushing a `finished` marker).
    pub tokens: Vec<i32>,
    /// Sampling-time logp per token in `tokens`.
    pub logps: Vec<f32>,
    /// Final chunk for this row: commit the accumulated response.
    pub finished: bool,
}

/// Parameters of a `lease_prompts` request (mirrors `GetBatchSpec`).
#[derive(Debug, Clone, PartialEq)]
pub struct LeaseSpec {
    /// Task whose controller feeds this worker (usually `"rollout"`).
    pub task: String,
    /// Lease owner (stats key; load-balancing group).
    pub worker: String,
    /// Max rows per lease.
    pub count: usize,
    /// Lease TTL in ms (must be >= 1).
    pub ttl_ms: u64,
    /// Server-side long-poll budget: `0` is a pure poll; otherwise the
    /// request waits until at least one row is ready, the queue closes,
    /// or the deadline passes.
    pub timeout_ms: u64,
    /// Columns to fetch for each leased row.
    pub columns: Vec<Column>,
}

impl LeaseSpec {
    /// A spec for `worker` with the standard defaults (task `rollout`,
    /// 1s TTL, 50ms long-poll, prompts column).
    pub fn new(worker: impl Into<String>, count: usize) -> Self {
        LeaseSpec {
            task: "rollout".into(),
            worker: worker.into(),
            count,
            ttl_ms: 1000,
            timeout_ms: 50,
            columns: vec![Column::Prompts],
        }
    }
}

/// Reply to `lease_prompts`.
#[derive(Debug, Clone)]
pub struct LeaseReply {
    /// `None` when no rows were available (retry unless `closed`).
    pub lease: Option<LeaseId>,
    /// The leased rows (empty iff `lease` is `None`).
    pub batch: Batch,
    /// The prompt stream is closed AND nothing from this task is in
    /// flight anywhere — the worker can exit. While other workers still
    /// hold leases this stays `false`: their rows may yet be requeued
    /// to this worker.
    pub closed: bool,
    /// Trace id minted for this lease (0 = untraced / telemetry off).
    /// The worker adopts it so generate/put_chunk spans on its side
    /// join the coordinator's lease→chunk→commit chain.
    pub trace: u64,
}

/// Column the finished policy version is committed under (same cell the
/// in-process rollout stage historically wrote).
fn version_column() -> Column {
    Column::Custom("version".into())
}

/// Most recent leases whose trace ids are kept for
/// [`RolloutManager::trace_of`]; older entries are evicted (lease ids
/// are monotonic, so smallest = oldest).
const LEASE_TRACE_CAP: usize = 4096;

/// Coordinator-side dispatcher for the elastic rollout pool.
pub struct RolloutManager {
    tq: Arc<TransferQueue>,
    table: LeaseTable,
    /// Trace id per live-ish lease (bounded; see [`LEASE_TRACE_CAP`]).
    traces: Mutex<BTreeMap<LeaseId, u64>>,
}

impl RolloutManager {
    pub fn new(tq: Arc<TransferQueue>) -> Self {
        RolloutManager {
            tq,
            table: LeaseTable::new(),
            traces: Mutex::new(BTreeMap::new()),
        }
    }

    /// Requeue rows of expired leases back onto their source controller.
    /// Called at the top of every verb, so detection needs no timer
    /// thread — liveness comes from peers polling for work.
    fn sweep(&self) {
        for (task, rows) in self.table.sweep_expired() {
            if let Some(ctrl) = self.tq.try_controller(&task) {
                ctrl.unconsume(&rows);
            }
        }
    }

    /// Stable DP-group id for a worker (feeds the controller's
    /// load-balancing policy and per-group stats).
    fn group_of(worker: &str) -> usize {
        worker
            .bytes()
            .fold(0usize, |a, b| a.wrapping_mul(31).wrapping_add(b as usize))
            % 1024
    }

    /// `lease_prompts`: pop up to `spec.count` ready prompt rows under a
    /// fresh lease, long-polling up to `spec.timeout_ms`. An empty reply
    /// means poll again (or exit, when `closed`).
    pub fn lease_prompts(&self, spec: &LeaseSpec) -> Result<LeaseReply> {
        if spec.worker.is_empty() {
            bail!("worker name must be non-empty");
        }
        if spec.count == 0 {
            bail!("lease count must be >= 1");
        }
        if spec.ttl_ms == 0 {
            // A zero TTL would expire before the first heartbeat and
            // livelock the pool on requeue — reject loudly instead.
            bail!("lease ttl_ms must be >= 1");
        }
        self.sweep();
        let Some(ctrl) = self.tq.try_controller(&spec.task) else {
            bail!("unknown task {:?}", spec.task);
        };
        let empty = || Batch {
            indices: vec![],
            rows: vec![],
            columns: spec.columns.clone(),
        };
        let group = Self::group_of(&spec.worker);
        // Prefer FULL leases — fixed-geometry engines pad partial
        // batches to their whole width, so sub-batch leases waste
        // decode — but never require them: a requeued remainder (a
        // crashed worker's tail) can be smaller than any batch and
        // would starve forever behind min = count (the feeder only
        // tops the pool up between iterations). So: long-poll for a
        // full batch, then take whatever is ready at the deadline.
        let outcome = if spec.timeout_ms == 0 {
            ctrl.poll(group, spec.count, 1)
        } else {
            let deadline =
                Instant::now() + Duration::from_millis(spec.timeout_ms);
            match ctrl.request_deadline(
                group,
                spec.count,
                spec.count,
                Some(deadline),
            ) {
                RequestOutcome::NotReady => ctrl.poll(group, spec.count, 1),
                done => done,
            }
        };
        match outcome {
            RequestOutcome::Ready(meta) => {
                let batch =
                    match self.tq.try_fetch(&meta.indices, &spec.columns) {
                        Ok(b) => b,
                        Err(e) => {
                            // Never strand rows on a failed fetch (e.g. a
                            // column the rollout graph does not carry).
                            ctrl.unconsume(&meta.indices);
                            return Err(e);
                        }
                    };
                let id = self.table.grant(
                    &spec.worker,
                    &spec.task,
                    &meta.indices,
                    Duration::from_millis(spec.ttl_ms),
                );
                // Every grant mints the trace the whole chain
                // (lease→chunk→commit→train) will share; disabled
                // telemetry mints nothing, keeping the wire byte-
                // identical to the pre-telemetry encoding.
                let trace = if crate::telemetry::enabled() {
                    let t = crate::telemetry::mint_trace();
                    let mut g = self.traces.lock().unwrap();
                    g.insert(id, t);
                    while g.len() > LEASE_TRACE_CAP {
                        g.pop_first();
                    }
                    t
                } else {
                    0
                };
                Ok(LeaseReply {
                    lease: Some(id),
                    batch,
                    closed: false,
                    trace,
                })
            }
            RequestOutcome::NotReady => Ok(LeaseReply {
                lease: None,
                batch: empty(),
                closed: false,
                trace: 0,
            }),
            RequestOutcome::Closed => Ok(LeaseReply {
                lease: None,
                batch: empty(),
                closed: self.table.in_flight_for(&spec.task) == 0,
                trace: 0,
            }),
        }
    }

    /// Trace id minted when `lease` was granted (0 = unknown/untraced).
    pub fn trace_of(&self, lease: LeaseId) -> u64 {
        self.traces
            .lock()
            .unwrap()
            .get(&lease)
            .copied()
            .unwrap_or(0)
    }

    /// `put_chunk`: stream partial generations. Rows flagged `finished`
    /// are committed to the queue (Responses + OldLogp + policy version)
    /// — at that instant downstream readiness fires for the row. The
    /// batch is validated and applied atomically against the lease
    /// table, so a rejected request leaves no partial lease state and
    /// the client's accounting matches the server's.
    pub fn put_chunk(
        &self,
        lease: LeaseId,
        version: u64,
        rows: &[ChunkRow],
    ) -> Result<()> {
        self.sweep();
        // Lease liveness FIRST: a zombie whose rows were requeued and
        // recommitted by an inheritor must get the (recoverable) "lease
        // unknown" error, not be misdiagnosed by the cell pre-flight
        // below. Doubles as the heartbeat.
        self.table.renew(lease, None)?;
        // Pre-flight: a finishing row commits three cells; if a foreign
        // writer already squatted any of them, fail BEFORE the lease
        // marks rows done — nothing is stranded, and the rows remain
        // requeueable when the lease eventually expires.
        let dp = self.tq.data_plane();
        for r in rows.iter().filter(|r| r.finished) {
            for col in
                [Column::Responses, Column::OldLogp, version_column()]
            {
                if dp.has_cell(r.index, &col) {
                    bail!(
                        "row {} already has a {col} cell — refusing to \
                         double-commit",
                        r.index
                    );
                }
            }
        }
        let committed = self.table.append_rows(lease, rows)?;
        for (index, tokens, logps) in committed {
            self.tq.put(index, Column::Responses, Value::I32s(tokens))?;
            self.tq.put(index, Column::OldLogp, Value::F32s(logps))?;
            self.tq.put(index, version_column(), Value::U64(version))?;
        }
        Ok(())
    }

    /// `renew_lease`: explicit heartbeat for chunks that take long to
    /// produce. `ttl = None` keeps the lease's granted TTL.
    pub fn renew_lease(
        &self,
        lease: LeaseId,
        ttl: Option<Duration>,
    ) -> Result<()> {
        self.sweep();
        self.table.renew(lease, ttl)
    }

    /// `worker_stats`: per-worker load/progress snapshot.
    pub fn worker_stats(&self) -> Vec<WorkerStat> {
        self.sweep();
        self.table.stats()
    }

    /// Rows currently leased and unfinished (drain barrier).
    pub fn in_flight(&self) -> usize {
        self.table.in_flight()
    }

    /// Rows leased from `task` and unfinished — the rollout half of the
    /// per-task `leased` stat in the `stats` verb. Pure read: callers
    /// that need freshness sweep once via
    /// [`RolloutManager::sweep_now`] first (not per task).
    pub fn in_flight_for(&self, task: &str) -> usize {
        self.table.in_flight_for(task)
    }

    /// Requeue expired leases now — the explicit form of the sweep
    /// every verb performs, for snapshot paths (`stats`) that read
    /// several per-task values and should pay for one sweep, not one
    /// per task.
    pub fn sweep_now(&self) {
        self.sweep();
    }

    /// Earliest rollout-lease expiry (`None` = no lease live) — the
    /// wake deadline for the session's expiry-driven sweeper thread.
    pub fn next_expiry(&self) -> Option<std::time::Instant> {
        self.table.next_expiry()
    }

    /// Install the lease table's expiry re-arm hook (fired on
    /// grant/renew so the sweeper re-arms instead of polling).
    pub fn set_expiry_hook(&self, f: crate::transfer_queue::WakeFn) {
        self.table.set_expiry_hook(f);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transfer_queue::TaskSpec;

    fn tq_with(prompts: usize) -> Arc<TransferQueue> {
        let tq = TransferQueue::builder()
            .storage_units(2)
            .task(TaskSpec::new("rollout", vec![Column::Prompts]))
            .task(TaskSpec::new("reward", vec![Column::Responses]))
            .task(TaskSpec::new(
                "train",
                vec![Column::Responses, Column::OldLogp],
            ))
            .build();
        for i in 0..prompts {
            tq.put_row(vec![(Column::Prompts, Value::I32s(vec![i as i32; 4]))])
                .unwrap();
        }
        tq
    }

    fn spec(worker: &str, ttl_ms: u64) -> LeaseSpec {
        LeaseSpec {
            ttl_ms,
            timeout_ms: 0,
            ..LeaseSpec::new(worker, 8)
        }
    }

    #[test]
    fn lease_then_stream_then_commit_unlocks_downstream() {
        let tq = tq_with(2);
        let m = RolloutManager::new(tq.clone());
        let reply = m.lease_prompts(&spec("w0", 5000)).unwrap();
        let lease = reply.lease.unwrap();
        assert_eq!(reply.batch.len(), 2);
        let a = reply.batch.indices[0];
        let b = reply.batch.indices[1];

        // Partial chunk: nothing visible downstream yet.
        m.put_chunk(
            lease,
            3,
            &[ChunkRow {
                index: a,
                tokens: vec![1, 2],
                logps: vec![-0.1, -0.2],
                finished: false,
            }],
        )
        .unwrap();
        assert_eq!(tq.controller("reward").ready_depth(), 0);

        // Finishing row `a` commits it while `b` is still decoding.
        m.put_chunk(
            lease,
            3,
            &[ChunkRow {
                index: a,
                tokens: vec![3],
                logps: vec![-0.3],
                finished: true,
            }],
        )
        .unwrap();
        assert_eq!(tq.controller("reward").ready_depth(), 1);
        assert_eq!(tq.controller("train").ready_depth(), 1);
        assert_eq!(
            tq.data_plane().get(a, &Column::Responses),
            Some(Value::I32s(vec![1, 2, 3]))
        );
        assert_eq!(
            tq.data_plane().get(a, &version_column()),
            Some(Value::U64(3))
        );
        assert_eq!(m.in_flight(), 1);

        m.put_chunk(
            lease,
            3,
            &[ChunkRow {
                index: b,
                tokens: vec![9],
                logps: vec![-0.9],
                finished: true,
            }],
        )
        .unwrap();
        assert_eq!(m.in_flight(), 0);
        assert_eq!(tq.controller("reward").ready_depth(), 2);
    }

    #[test]
    fn lease_long_poll_waits_for_prompts() {
        let tq = tq_with(0);
        let m = Arc::new(RolloutManager::new(tq.clone()));
        let m2 = m.clone();
        let h = std::thread::spawn(move || {
            let s = LeaseSpec {
                timeout_ms: 2000,
                ..LeaseSpec::new("w", 1)
            };
            m2.lease_prompts(&s).unwrap()
        });
        std::thread::sleep(Duration::from_millis(20));
        tq.put_row(vec![(Column::Prompts, Value::I32s(vec![7; 4]))])
            .unwrap();
        let reply = h.join().unwrap();
        assert!(reply.lease.is_some(), "long-poll woken by ingest");
        assert_eq!(reply.batch.len(), 1);
    }

    #[test]
    fn sub_batch_remainder_leases_after_the_full_batch_deadline() {
        // 3 ready rows, count 8: the full-batch preference waits out the
        // timeout, then the fallback takes what is there — a requeued
        // remainder can never starve behind min = count.
        let tq = tq_with(3);
        let m = RolloutManager::new(tq);
        let s = LeaseSpec {
            timeout_ms: 30,
            ..LeaseSpec::new("w", 8)
        };
        let reply = m.lease_prompts(&s).unwrap();
        assert!(reply.lease.is_some());
        assert_eq!(reply.batch.len(), 3);
    }

    #[test]
    fn expired_lease_requeues_and_rejects_zombie() {
        let tq = tq_with(2);
        let m = RolloutManager::new(tq.clone());
        let first = m.lease_prompts(&spec("dead", 30)).unwrap();
        let dead_lease = first.lease.unwrap();
        assert_eq!(first.batch.len(), 2);
        // Pool exhausted while the lease is alive.
        assert!(m.lease_prompts(&spec("live", 30)).unwrap().lease.is_none());

        std::thread::sleep(Duration::from_millis(60));
        // The next poll sweeps and re-serves the same rows.
        let second = m.lease_prompts(&spec("live", 5000)).unwrap();
        assert_eq!(second.batch.indices, first.batch.indices);

        // Zombie chunks for the dead lease are rejected...
        let zombie = m.put_chunk(
            dead_lease,
            1,
            &[ChunkRow {
                index: first.batch.indices[0],
                tokens: vec![5],
                logps: vec![-0.5],
                finished: true,
            }],
        );
        assert!(zombie.is_err());
        // ...so the survivor's commit is the only one.
        for idx in &second.batch.indices {
            m.put_chunk(
                second.lease.unwrap(),
                1,
                &[ChunkRow {
                    index: *idx,
                    tokens: vec![7],
                    logps: vec![-0.7],
                    finished: true,
                }],
            )
            .unwrap();
        }
        assert_eq!(tq.controller("reward").ready_depth(), 2);
        let stats = m.worker_stats();
        let dead = stats.iter().find(|s| s.worker == "dead").unwrap();
        assert_eq!(dead.requeued_rows, 2);
        assert_eq!(dead.completed_rows, 0);
        let live = stats.iter().find(|s| s.worker == "live").unwrap();
        assert_eq!(live.completed_rows, 2);
    }

    #[test]
    fn closed_reply_waits_for_in_flight_rows() {
        let tq = tq_with(1);
        let m = RolloutManager::new(tq.clone());
        let reply = m.lease_prompts(&spec("a", 40)).unwrap();
        assert_eq!(reply.batch.len(), 1);
        tq.close();
        // Queue closed but a's row is in flight: b must keep polling
        // (it may inherit the row if a dies).
        let b = m.lease_prompts(&spec("b", 40)).unwrap();
        assert!(b.lease.is_none() && !b.closed);
        std::thread::sleep(Duration::from_millis(80));
        // a expired -> requeued -> b gets the row even post-close (drain).
        let b2 = m.lease_prompts(&spec("b", 5000)).unwrap();
        assert_eq!(b2.batch.len(), 1);
        m.put_chunk(
            b2.lease.unwrap(),
            0,
            &[ChunkRow {
                index: b2.batch.indices[0],
                tokens: vec![1],
                logps: vec![-0.1],
                finished: true,
            }],
        )
        .unwrap();
        // Everything committed: now the pool reports closed.
        let done = m.lease_prompts(&spec("b", 40)).unwrap();
        assert!(done.lease.is_none() && done.closed);
    }

    #[test]
    fn lease_rejects_bad_requests() {
        let m = RolloutManager::new(tq_with(1));
        assert!(m.lease_prompts(&spec("", 100)).is_err(), "empty worker");
        assert!(
            m.lease_prompts(&LeaseSpec {
                timeout_ms: 0,
                ..LeaseSpec::new("w", 0)
            })
            .is_err(),
            "zero count"
        );
        assert!(
            m.lease_prompts(&spec("w", 0)).is_err(),
            "zero ttl would livelock on requeue"
        );
        // Unknown task -> error, not panic.
        assert!(m
            .lease_prompts(&LeaseSpec {
                task: "nope".into(),
                timeout_ms: 0,
                ..LeaseSpec::new("w", 8)
            })
            .is_err());
    }

    #[test]
    fn failed_fetch_does_not_strand_rows() {
        let tq = tq_with(1);
        let m = RolloutManager::new(tq.clone());
        // Ask for a column the row does not carry: fetch fails...
        let bad = LeaseSpec {
            columns: vec![Column::Rewards],
            timeout_ms: 0,
            ..LeaseSpec::new("w", 8)
        };
        assert!(m.lease_prompts(&bad).is_err());
        // ...but the row is immediately leasable again.
        let ok = m.lease_prompts(&spec("w", 100)).unwrap();
        assert_eq!(ok.batch.len(), 1);
    }

    #[test]
    fn granted_leases_mint_unique_traces() {
        let _gate = crate::telemetry::test_enable_gate();
        crate::telemetry::set_enabled(Some(true));
        let tq = tq_with(2);
        let m = RolloutManager::new(tq.clone());
        let s = LeaseSpec {
            ttl_ms: 5000,
            timeout_ms: 0,
            ..LeaseSpec::new("w", 1)
        };
        let a = m.lease_prompts(&s).unwrap();
        let b = m.lease_prompts(&s).unwrap();
        assert_ne!(a.trace, 0);
        assert_ne!(b.trace, 0);
        assert_ne!(a.trace, b.trace, "each lease gets its own trace");
        assert!(a.trace <= crate::telemetry::TRACE_ID_MASK);
        assert_eq!(m.trace_of(a.lease.unwrap()), a.trace);
        assert_eq!(m.trace_of(b.lease.unwrap()), b.trace);
        // Telemetry off: grants stop minting entirely.
        crate::telemetry::set_enabled(Some(false));
        tq.put_row(vec![(Column::Prompts, Value::I32s(vec![9; 4]))])
            .unwrap();
        let c = m.lease_prompts(&s).unwrap();
        assert!(c.lease.is_some());
        assert_eq!(c.trace, 0);
        assert_eq!(m.trace_of(c.lease.unwrap()), 0);
        crate::telemetry::set_enabled(None);
    }

    #[test]
    fn put_chunk_refuses_to_double_commit_squatted_cells() {
        let tq = tq_with(1);
        let m = RolloutManager::new(tq.clone());
        let reply = m.lease_prompts(&spec("w", 5000)).unwrap();
        let idx = reply.batch.indices[0];
        // A foreign writer commits Responses behind the manager's back.
        tq.put(idx, Column::Responses, Value::I32s(vec![42])).unwrap();
        let res = m.put_chunk(
            reply.lease.unwrap(),
            0,
            &[ChunkRow {
                index: idx,
                tokens: vec![1],
                logps: vec![-0.1],
                finished: true,
            }],
        );
        assert!(res.is_err(), "pre-flight catches the squatted cell");
        // The row was NOT marked done, so it stays requeueable.
        assert_eq!(m.in_flight(), 1);
    }
}
