//! Coordinator-side rollout manager: leases prompt groups to an elastic
//! pool of workers and streams their partial generations into the
//! TransferQueue.
//!
//! The manager sits between the service dispatcher and the queue:
//!
//! ```text
//!  lease_prompts ─▶ task controller (exactly-once pop, long-poll) ─▶ Lease
//!  put_chunk     ─▶ LeaseTable partial-row state ─┬─(row finished)──▶
//!                                                 └▶ Responses+OldLogp
//!  (lease expires) ─▶ Controller::unconsume ─▶ next lease_prompts
//! ```
//!
//! Load balancing is pull-based (the paper's §3.3 dynamic view): a worker
//! asks for work exactly when it has capacity, so requeued rows land on
//! the least-loaded peer — the one polling — without any push-side
//! placement logic. Expiry is detected lazily: every verb sweeps the
//! lease table first, so a crashed worker's rows reappear as soon as any
//! peer asks for more work (bounded by the peers' long-poll timeout).
//! Downstream stages that key on `Responses` (reference, reward) unlock
//! per row the moment that row's final chunk lands, while the long tail
//! of its group is still decoding — the streaming-overlap claim made
//! concrete.

use std::collections::{BTreeMap, HashSet};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::fleet::{
    DupMode, EngineSpec, FleetOptions, FleetRouter, FleetStats,
    RoutingPolicy, RowPlan,
};
use crate::transfer_queue::{
    Batch, Column, GlobalIndex, RequestOutcome, TransferQueue, Value,
};

use super::lease::{LeaseId, LeaseTable, WorkerStat};

/// One row's increment in a `put_chunk` request.
#[derive(Debug, Clone, PartialEq)]
pub struct ChunkRow {
    pub index: GlobalIndex,
    /// Response tokens decoded since the last chunk (may be empty when
    /// only flushing a `finished` marker).
    pub tokens: Vec<i32>,
    /// Sampling-time logp per token in `tokens`.
    pub logps: Vec<f32>,
    /// Final chunk for this row: commit the accumulated response.
    pub finished: bool,
}

/// Parameters of a `lease_prompts` request (mirrors `GetBatchSpec`).
#[derive(Debug, Clone, PartialEq)]
pub struct LeaseSpec {
    /// Task whose controller feeds this worker (usually `"rollout"`).
    pub task: String,
    /// Lease owner (stats key; load-balancing group).
    pub worker: String,
    /// Max rows per lease.
    pub count: usize,
    /// Lease TTL in ms (must be >= 1).
    pub ttl_ms: u64,
    /// Server-side long-poll budget: `0` is a pure poll; otherwise the
    /// request waits until at least one row is ready, the queue closes,
    /// or the deadline passes.
    pub timeout_ms: u64,
    /// Columns to fetch for each leased row.
    pub columns: Vec<Column>,
    /// Capability report of the worker's engine, registered with the
    /// fleet on every poll. Optional: old workers send none and still
    /// participate in routing (with unknown capabilities).
    pub engine: Option<EngineSpec>,
}

impl LeaseSpec {
    /// A spec for `worker` with the standard defaults (task `rollout`,
    /// 1s TTL, 50ms long-poll, prompts column).
    pub fn new(worker: impl Into<String>, count: usize) -> Self {
        LeaseSpec {
            task: "rollout".into(),
            worker: worker.into(),
            count,
            ttl_ms: 1000,
            timeout_ms: 50,
            columns: vec![Column::Prompts],
            engine: None,
        }
    }
}

/// Reply to `lease_prompts`.
#[derive(Debug, Clone)]
pub struct LeaseReply {
    /// `None` when no rows were available (retry unless `closed`).
    pub lease: Option<LeaseId>,
    /// The leased rows (empty iff `lease` is `None`).
    pub batch: Batch,
    /// The prompt stream is closed AND nothing from this task is in
    /// flight anywhere — the worker can exit. While other workers still
    /// hold leases this stays `false`: their rows may yet be requeued
    /// to this worker.
    pub closed: bool,
    /// Trace id minted for this lease (0 = untraced / telemetry off).
    /// The worker adopts it so generate/put_chunk spans on its side
    /// join the coordinator's lease→chunk→commit chain.
    pub trace: u64,
}

/// Column the finished policy version is committed under (same cell the
/// in-process rollout stage historically wrote).
fn version_column() -> Column {
    Column::Custom("version".into())
}

/// Most recent leases whose trace ids are kept for
/// [`RolloutManager::trace_of`]; older entries are evicted (lease ids
/// are monotonic, so smallest = oldest).
const LEASE_TRACE_CAP: usize = 4096;

/// Coordinator-side dispatcher for the elastic rollout pool.
pub struct RolloutManager {
    tq: Arc<TransferQueue>,
    table: LeaseTable,
    /// Routing policy layer over lease dispatch (load-balance /
    /// fallback / hedge / mirror). Advisory bookkeeping only — the
    /// lease table stays the single source of truth for exactly-once.
    router: FleetRouter,
    /// Trace id per live-ish lease (bounded; see [`LEASE_TRACE_CAP`]).
    traces: Mutex<BTreeMap<LeaseId, u64>>,
}

impl RolloutManager {
    pub fn new(tq: Arc<TransferQueue>) -> Self {
        RolloutManager {
            tq,
            table: LeaseTable::new(),
            router: FleetRouter::default(),
            traces: Mutex::new(BTreeMap::new()),
        }
    }

    /// Replace the fleet routing options (policy + hedge/mirror
    /// tunables) — the `[fleet]` config table applied at serve time.
    pub fn configure_fleet(&self, options: FleetOptions) {
        crate::log_info!(
            "rollout",
            "fleet routing policy: {}",
            options.policy.name()
        );
        self.router.configure(options);
    }

    /// Register a statically-configured engine spec (the `[fleet]`
    /// config table's engine entries; workers that attach later refresh
    /// their own via `lease_prompts`).
    pub fn register_engine(&self, worker: &str, spec: EngineSpec) {
        self.router.register_engine(worker, spec, "config");
    }

    /// Routing-layer snapshot (`stats.fleet`).
    pub fn fleet_stats(&self) -> FleetStats {
        self.sweep();
        self.router.stats()
    }

    /// Requeue rows of expired leases back onto their source controller.
    /// Called at the top of every verb, so detection needs no timer
    /// thread — liveness comes from peers polling for work. The router
    /// decides which swept rows actually requeue: a row whose hedge /
    /// mirror duplicate is still live (or already committed) must not.
    fn sweep(&self) {
        let swept = self.table.sweep_expired();
        if swept.is_empty() {
            return;
        }
        for (task, rows) in self.router.on_leases_swept(&swept) {
            if let Some(ctrl) = self.tq.try_controller(&task) {
                ctrl.unconsume(&rows);
            }
        }
    }

    /// Stable DP-group id for a worker (feeds the controller's
    /// load-balancing policy and per-group stats).
    fn group_of(worker: &str) -> usize {
        worker
            .bytes()
            .fold(0usize, |a, b| a.wrapping_mul(31).wrapping_add(b as usize))
            % 1024
    }

    /// `lease_prompts`: pop up to `spec.count` ready prompt rows under a
    /// fresh lease, long-polling up to `spec.timeout_ms`. An empty reply
    /// means poll again (or exit, when `closed`).
    pub fn lease_prompts(&self, spec: &LeaseSpec) -> Result<LeaseReply> {
        if spec.worker.is_empty() {
            bail!("worker name must be non-empty");
        }
        if spec.count == 0 {
            bail!("lease count must be >= 1");
        }
        if spec.ttl_ms == 0 {
            // A zero TTL would expire before the first heartbeat and
            // livelock the pool on requeue — reject loudly instead.
            bail!("lease ttl_ms must be >= 1");
        }
        self.sweep();
        let Some(ctrl) = self.tq.try_controller(&spec.task) else {
            bail!("unknown task {:?}", spec.task);
        };
        let empty = || Batch {
            indices: vec![],
            rows: vec![],
            columns: spec.columns.clone(),
        };
        // Fleet routing, poll side: register the poll (and the engine
        // spec riding it), then let the router defer a loaded worker in
        // favor of an actively-polling idler (load-balance / fallback).
        // Deferral is only consulted when rows are actually queued: an
        // empty queue has nothing to defer, and eating the worker's
        // long-poll (plus counting `lb_deferrals`) for it would just
        // add dispatch latency and noise.
        self.router.note_poll(&spec.worker, spec.engine.as_ref());
        if ctrl.ready_depth() > 0
            && self.router.should_defer(&spec.worker, &self.table.owner_load())
        {
            return Ok(LeaseReply {
                lease: None,
                batch: empty(),
                closed: false,
                trace: 0,
            });
        }
        let group = Self::group_of(&spec.worker);
        // Prefer FULL leases — fixed-geometry engines pad partial
        // batches to their whole width, so sub-batch leases waste
        // decode — but never require them: a requeued remainder (a
        // crashed worker's tail) can be smaller than any batch and
        // would starve forever behind min = count (the feeder only
        // tops the pool up between iterations). So: long-poll for a
        // full batch, then take whatever is ready at the deadline.
        let outcome = if spec.timeout_ms == 0 {
            ctrl.poll(group, spec.count, 1)
        } else {
            let deadline =
                Instant::now() + Duration::from_millis(spec.timeout_ms);
            match ctrl.request_deadline(
                group,
                spec.count,
                spec.count,
                Some(deadline),
            ) {
                RequestOutcome::NotReady => ctrl.poll(group, spec.count, 1),
                done => done,
            }
        };
        match outcome {
            RequestOutcome::Ready(meta) => {
                let batch =
                    match self.tq.try_fetch(&meta.indices, &spec.columns) {
                        Ok(b) => b,
                        Err(e) => {
                            // Never strand rows on a failed fetch (e.g. a
                            // column the rollout graph does not carry).
                            ctrl.unconsume(&meta.indices);
                            return Err(e);
                        }
                    };
                let id = self.table.grant(
                    &spec.worker,
                    &spec.task,
                    &meta.indices,
                    Duration::from_millis(spec.ttl_ms),
                );
                self.router.on_grant(id, &spec.worker, &spec.task);
                // Every grant mints the trace the whole chain
                // (lease→chunk→commit→train) will share; disabled
                // telemetry mints nothing, keeping the wire byte-
                // identical to the pre-telemetry encoding.
                let trace = self.mint_trace_for(id);
                Ok(LeaseReply {
                    lease: Some(id),
                    batch,
                    closed: false,
                    trace,
                })
            }
            RequestOutcome::NotReady => {
                // No queued rows for an idle poller: under hedge /
                // mirror routing this is the moment to duplicate a
                // straggler's remaining rows instead of going home
                // empty-handed.
                if let Some(reply) = self.try_duplicate(spec) {
                    return Ok(reply);
                }
                Ok(LeaseReply {
                    lease: None,
                    batch: empty(),
                    closed: false,
                    trace: 0,
                })
            }
            RequestOutcome::Closed => Ok(LeaseReply {
                lease: None,
                batch: empty(),
                closed: self.table.in_flight_for(&spec.task) == 0,
                trace: 0,
            }),
        }
    }

    /// Trace id minted when `lease` was granted (0 = unknown/untraced).
    pub fn trace_of(&self, lease: LeaseId) -> u64 {
        self.traces
            .lock()
            .unwrap()
            .get(&lease)
            .copied()
            .unwrap_or(0)
    }

    fn mint_trace_for(&self, id: LeaseId) -> u64 {
        if !crate::telemetry::enabled() {
            return 0;
        }
        let t = crate::telemetry::mint_trace();
        let mut g = self.traces.lock().unwrap();
        g.insert(id, t);
        while g.len() > LEASE_TRACE_CAP {
            g.pop_first();
        }
        t
    }

    /// Hedge/mirror duplication: grant a straggler's remaining rows to
    /// an idle poller as a *second* lease racing the first. Returns
    /// `None` when the policy, the candidates, or the rows say no —
    /// the caller then sends the ordinary empty reply.
    fn try_duplicate(&self, spec: &LeaseSpec) -> Option<LeaseReply> {
        // The candidate pick *reserves* the primary inside the router
        // lock, so two idle pollers racing this path can never both
        // duplicate the same straggler. Every bail-out before
        // `record_dup` (which consumes the reservation) must release.
        let (primary, mode) = match self.router.policy() {
            RoutingPolicy::Hedge => (
                self.router.hedge_candidate(&spec.worker, &spec.task)?,
                DupMode::Hedge,
            ),
            RoutingPolicy::Mirror => (
                self.router.mirror_candidate(&spec.worker, &spec.task)?,
                DupMode::Mirror,
            ),
            _ => return None,
        };
        let t0 = crate::telemetry::now_us();
        let rows: Vec<GlobalIndex> = match self.table.undone_rows(primary)
        {
            Some(v) => v.into_iter().take(spec.count).collect(),
            None => {
                self.router.release_duplicate(primary);
                return None;
            }
        };
        if rows.is_empty() {
            self.router.release_duplicate(primary);
            return None;
        }
        // The straggler's prompt cells can be gone by now (won, trained
        // and reclaimed since the candidate pick) — then there is
        // simply nothing left worth duplicating.
        let batch = match self.tq.try_fetch(&rows, &spec.columns) {
            Ok(b) => b,
            Err(_) => {
                self.router.release_duplicate(primary);
                return None;
            }
        };
        let dup = self.table.grant(
            &spec.worker,
            &spec.task,
            &rows,
            Duration::from_millis(spec.ttl_ms),
        );
        self.router
            .record_dup(primary, dup, &spec.worker, &spec.task, &rows, mode);
        // Close the duplicate-grant race: a row the primary finished
        // (or lost) between the `undone_rows` snapshot above and
        // `record_dup` was committed as a *plain* row — no DupEntry
        // existed to arbitrate, so the pair must never contend for it.
        // Discard the duplicate's copy and mark the entry foreign so
        // neither side's chunks commit it again or requeue it.
        let still_undone: HashSet<GlobalIndex> = self
            .table
            .undone_rows(primary)
            .map(|v| v.into_iter().collect())
            .unwrap_or_default();
        let stale: Vec<GlobalIndex> = rows
            .iter()
            .copied()
            .filter(|i| !still_undone.contains(i))
            .collect();
        if !stale.is_empty() {
            for idx in &stale {
                if let Some((t, _)) = self.table.take_row_discard(dup, *idx)
                {
                    self.router.note_dropped(t.len());
                }
                self.router.note_foreign_commit(dup, *idx);
            }
            if stale.len() == rows.len() {
                // Nothing left to race: discarding the last row retired
                // the duplicate lease in the table already.
                self.router.forget_lease(dup);
                return None;
            }
        }
        let trace = self.mint_trace_for(dup);
        crate::telemetry::record_span(
            match mode {
                DupMode::Hedge => "hedge",
                DupMode::Mirror => "mirror",
            },
            "fleet",
            trace,
            t0,
            crate::telemetry::now_us(),
        );
        crate::log_info!(
            "rollout",
            "{} lease {primary} -> duplicate {dup} on {} ({} rows)",
            match mode {
                DupMode::Hedge => "hedging",
                DupMode::Mirror => "mirroring",
            },
            spec.worker,
            rows.len()
        );
        Some(LeaseReply {
            lease: Some(dup),
            batch,
            closed: false,
            trace,
        })
    }

    /// `put_chunk`: stream partial generations. Rows flagged `finished`
    /// are committed to the queue (Responses + OldLogp + policy version)
    /// — at that instant downstream readiness fires for the row. The
    /// batch is validated and applied atomically against the lease
    /// table, so a rejected request leaves no partial lease state and
    /// the client's accounting matches the server's.
    pub fn put_chunk(
        &self,
        lease: LeaseId,
        version: u64,
        rows: &[ChunkRow],
    ) -> Result<()> {
        self.sweep();
        // Lease liveness FIRST: a zombie whose rows were requeued and
        // recommitted by an inheritor must get the (recoverable) "lease
        // unknown" error, not be misdiagnosed by the cell pre-flight
        // below. Doubles as the heartbeat.
        self.table.renew(lease, None)?;
        // Shape checks BEFORE the router sees the chunk: filter_chunk
        // claims duplicated-row winners as a side effect, and a
        // malformed batch must bounce without routing state changing.
        let mut seen = HashSet::new();
        for r in rows {
            if r.tokens.len() != r.logps.len() {
                bail!(
                    "chunk for {}: {} tokens but {} logps",
                    r.index,
                    r.tokens.len(),
                    r.logps.len()
                );
            }
            if !seen.insert(r.index) {
                bail!("row {} appears twice in one chunk batch", r.index);
            }
        }
        // Routing decision, atomic per chunk: which rows this lease
        // commits, which divert (this lease lost the row to a hedge /
        // mirror duplicate), and which losers to revoke on a win. The
        // winner claims returned alongside the plans are PROVISIONAL:
        // every failure path between here and the rows' cells landing
        // must roll them back, or a claim whose commit never happened
        // would strand the row — the partner's chunks divert against
        // it and the sweep treats it as already committed.
        let shape: Vec<(GlobalIndex, bool, usize)> = rows
            .iter()
            .map(|r| (r.index, r.finished, r.tokens.len()))
            .collect();
        let (mut plans, claimed) = self.router.filter_chunk(lease, &shape);
        // Pre-flight commit rows: a finishing row commits three cells.
        // A squatted cell on a *duplicated* row is the duplicate-grant
        // race resolving against us (the row committed before the pair
        // existed) — demote our copy to a drop and move on. On a plain
        // row it is a real protocol violation: fail BEFORE the lease
        // marks rows done, so nothing is stranded and the rows remain
        // requeueable when the lease eventually expires.
        let dp = self.tq.data_plane();
        for (r, plan) in rows.iter().zip(plans.iter_mut()) {
            if !r.finished || !matches!(plan, RowPlan::Commit { .. }) {
                continue;
            }
            for col in
                [Column::Responses, Column::OldLogp, version_column()]
            {
                if dp.has_cell(r.index, &col) {
                    if self.router.note_foreign_commit(lease, r.index) {
                        *plan = RowPlan::Drop;
                        break;
                    }
                    self.router.rollback_claims(lease, &claimed);
                    bail!(
                        "row {} already has a {col} cell — refusing to \
                         double-commit",
                        r.index
                    );
                }
            }
        }
        let commit: Vec<ChunkRow> = rows
            .iter()
            .zip(&plans)
            .filter(|(_, p)| matches!(p, RowPlan::Commit { .. }))
            .map(|(r, _)| r.clone())
            .collect();
        let committed = match self.table.append_rows(lease, &commit) {
            Ok(c) => c,
            Err(e) => {
                self.router.rollback_claims(lease, &claimed);
                return Err(e);
            }
        };
        let claimed_set: HashSet<GlobalIndex> =
            claimed.iter().copied().collect();
        let mut cells_done: HashSet<GlobalIndex> = HashSet::new();
        for (index, tokens, logps) in committed {
            let put = (|| -> Result<()> {
                self.tq.put(
                    index,
                    Column::Responses,
                    Value::I32s(tokens.clone()),
                )?;
                self.tq.put(index, Column::OldLogp, Value::F32s(logps))?;
                self.tq.put(index, version_column(), Value::U64(version))
            })();
            if let Err(e) = put {
                // Roll back only the claims whose cells never landed —
                // rows already fully committed keep their (now
                // confirmed) winner.
                let unlanded: Vec<GlobalIndex> = claimed
                    .iter()
                    .copied()
                    .filter(|i| !cells_done.contains(i))
                    .collect();
                self.router.rollback_claims(lease, &unlanded);
                return Err(e);
            }
            cells_done.insert(index);
            if claimed_set.contains(&index) {
                self.router.confirm_claim(lease, index);
            }
            self.router.note_committed(index, lease, &tokens);
        }
        // Resolve the duplicated rows this chunk decided: revoke the
        // losers' copies of rows this lease just won, and fold this
        // lease's own diverted rows (it lost them to the other engine)
        // back into the router's accounting. A lease whose last undone
        // row is discarded retires; its owner's next verb gets the
        // recoverable "lease unknown" error and re-leases.
        for (r, plan) in rows.iter().zip(&plans) {
            match plan {
                RowPlan::Commit { losers } => {
                    for l in losers {
                        if let Some((t, _)) =
                            self.table.take_row_discard(*l, r.index)
                        {
                            self.router.note_dropped(t.len());
                        }
                        if !self.table.is_live(*l) {
                            self.router.forget_lease(*l);
                        }
                    }
                }
                RowPlan::Drop => {
                    if let Some((t, _)) =
                        self.table.take_row_discard(lease, r.index)
                    {
                        self.router.note_dropped(t.len());
                    }
                    self.router.note_dropped(r.tokens.len());
                }
                RowPlan::Compare => {
                    let mut full = self
                        .table
                        .take_row_discard(lease, r.index)
                        .map(|(t, _)| t)
                        .unwrap_or_default();
                    full.extend_from_slice(&r.tokens);
                    self.router.note_dropped(full.len());
                    self.router.resolve_mirror(r.index, full);
                }
            }
        }
        if !self.table.is_live(lease) {
            self.router.forget_lease(lease);
        }
        Ok(())
    }

    /// `fail_lease`: the worker's engine errored mid-generation —
    /// revoke the lease and requeue its rows *now* instead of waiting
    /// out the TTL (the fallback routing path; accepted under every
    /// policy). Idempotent: an already-dead lease is a no-op, because
    /// failure reports race the TTL sweep by design.
    pub fn fail_lease(&self, lease: LeaseId, reason: &str) -> Result<()> {
        self.sweep();
        let Some(revoked) = self.table.revoke(lease) else {
            return Ok(());
        };
        crate::log_warn!(
            "rollout",
            "lease {lease} failed on {} ({reason}); {} rows back to \
             {}",
            revoked.owner,
            revoked.rows.len(),
            revoked.task
        );
        let rows = self.router.on_lease_failed(&revoked);
        if !rows.is_empty() {
            if let Some(ctrl) = self.tq.try_controller(&revoked.task) {
                ctrl.unconsume(&rows);
            }
        }
        Ok(())
    }

    /// `renew_lease`: explicit heartbeat for chunks that take long to
    /// produce. `ttl = None` keeps the lease's granted TTL.
    pub fn renew_lease(
        &self,
        lease: LeaseId,
        ttl: Option<Duration>,
    ) -> Result<()> {
        self.sweep();
        self.table.renew(lease, ttl)
    }

    /// `worker_stats`: per-worker load/progress snapshot, with each
    /// worker's engine spec (when the fleet registry knows one)
    /// attached.
    pub fn worker_stats(&self) -> Vec<WorkerStat> {
        self.sweep();
        let mut stats = self.table.stats();
        let fleet = self.router.stats();
        for s in &mut stats {
            if let Some(e) =
                fleet.engines.iter().find(|e| e.worker == s.worker)
            {
                if e.spec_reported {
                    let mut spec = e.spec.clone();
                    spec.observed_tps = e.observed_tps;
                    s.engine = Some(spec);
                }
            }
        }
        stats
    }

    /// Rows currently leased and unfinished (drain barrier).
    pub fn in_flight(&self) -> usize {
        self.table.in_flight()
    }

    /// Rows leased from `task` and unfinished — the rollout half of the
    /// per-task `leased` stat in the `stats` verb. Pure read: callers
    /// that need freshness sweep once via
    /// [`RolloutManager::sweep_now`] first (not per task).
    pub fn in_flight_for(&self, task: &str) -> usize {
        self.table.in_flight_for(task)
    }

    /// Per-task cumulative lease books for rollout leases (see
    /// [`crate::transfer_queue::LeaseAccounting`]).
    pub fn accounting(
        &self,
    ) -> std::collections::HashMap<String, crate::transfer_queue::LeaseAccounting>
    {
        self.table.accounting()
    }

    /// Requeue expired leases now — the explicit form of the sweep
    /// every verb performs, for snapshot paths (`stats`) that read
    /// several per-task values and should pay for one sweep, not one
    /// per task.
    pub fn sweep_now(&self) {
        self.sweep();
    }

    /// Earliest rollout-lease expiry (`None` = no lease live) — the
    /// wake deadline for the session's expiry-driven sweeper thread.
    pub fn next_expiry(&self) -> Option<std::time::Instant> {
        self.table.next_expiry()
    }

    /// Install the lease table's expiry re-arm hook (fired on
    /// grant/renew so the sweeper re-arms instead of polling).
    pub fn set_expiry_hook(&self, f: crate::transfer_queue::WakeFn) {
        self.table.set_expiry_hook(f);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transfer_queue::TaskSpec;

    fn tq_with(prompts: usize) -> Arc<TransferQueue> {
        let tq = TransferQueue::builder()
            .storage_units(2)
            .task(TaskSpec::new("rollout", vec![Column::Prompts]))
            .task(TaskSpec::new("reward", vec![Column::Responses]))
            .task(TaskSpec::new(
                "train",
                vec![Column::Responses, Column::OldLogp],
            ))
            .build();
        for i in 0..prompts {
            tq.put_row(vec![(Column::Prompts, Value::I32s(vec![i as i32; 4]))])
                .unwrap();
        }
        tq
    }

    fn spec(worker: &str, ttl_ms: u64) -> LeaseSpec {
        LeaseSpec {
            ttl_ms,
            timeout_ms: 0,
            ..LeaseSpec::new(worker, 8)
        }
    }

    #[test]
    fn lease_then_stream_then_commit_unlocks_downstream() {
        let tq = tq_with(2);
        let m = RolloutManager::new(tq.clone());
        let reply = m.lease_prompts(&spec("w0", 5000)).unwrap();
        let lease = reply.lease.unwrap();
        assert_eq!(reply.batch.len(), 2);
        let a = reply.batch.indices[0];
        let b = reply.batch.indices[1];

        // Partial chunk: nothing visible downstream yet.
        m.put_chunk(
            lease,
            3,
            &[ChunkRow {
                index: a,
                tokens: vec![1, 2],
                logps: vec![-0.1, -0.2],
                finished: false,
            }],
        )
        .unwrap();
        assert_eq!(tq.controller("reward").ready_depth(), 0);

        // Finishing row `a` commits it while `b` is still decoding.
        m.put_chunk(
            lease,
            3,
            &[ChunkRow {
                index: a,
                tokens: vec![3],
                logps: vec![-0.3],
                finished: true,
            }],
        )
        .unwrap();
        assert_eq!(tq.controller("reward").ready_depth(), 1);
        assert_eq!(tq.controller("train").ready_depth(), 1);
        assert_eq!(
            tq.data_plane().get(a, &Column::Responses),
            Some(Value::I32s(vec![1, 2, 3]))
        );
        assert_eq!(
            tq.data_plane().get(a, &version_column()),
            Some(Value::U64(3))
        );
        assert_eq!(m.in_flight(), 1);

        m.put_chunk(
            lease,
            3,
            &[ChunkRow {
                index: b,
                tokens: vec![9],
                logps: vec![-0.9],
                finished: true,
            }],
        )
        .unwrap();
        assert_eq!(m.in_flight(), 0);
        assert_eq!(tq.controller("reward").ready_depth(), 2);
    }

    #[test]
    fn lease_long_poll_waits_for_prompts() {
        let tq = tq_with(0);
        let m = Arc::new(RolloutManager::new(tq.clone()));
        let m2 = m.clone();
        let h = std::thread::spawn(move || {
            let s = LeaseSpec {
                timeout_ms: 2000,
                ..LeaseSpec::new("w", 1)
            };
            m2.lease_prompts(&s).unwrap()
        });
        std::thread::sleep(Duration::from_millis(20));
        tq.put_row(vec![(Column::Prompts, Value::I32s(vec![7; 4]))])
            .unwrap();
        let reply = h.join().unwrap();
        assert!(reply.lease.is_some(), "long-poll woken by ingest");
        assert_eq!(reply.batch.len(), 1);
    }

    #[test]
    fn sub_batch_remainder_leases_after_the_full_batch_deadline() {
        // 3 ready rows, count 8: the full-batch preference waits out the
        // timeout, then the fallback takes what is there — a requeued
        // remainder can never starve behind min = count.
        let tq = tq_with(3);
        let m = RolloutManager::new(tq);
        let s = LeaseSpec {
            timeout_ms: 30,
            ..LeaseSpec::new("w", 8)
        };
        let reply = m.lease_prompts(&s).unwrap();
        assert!(reply.lease.is_some());
        assert_eq!(reply.batch.len(), 3);
    }

    #[test]
    fn expired_lease_requeues_and_rejects_zombie() {
        let tq = tq_with(2);
        let m = RolloutManager::new(tq.clone());
        let first = m.lease_prompts(&spec("dead", 30)).unwrap();
        let dead_lease = first.lease.unwrap();
        assert_eq!(first.batch.len(), 2);
        // Pool exhausted while the lease is alive.
        assert!(m.lease_prompts(&spec("live", 30)).unwrap().lease.is_none());

        std::thread::sleep(Duration::from_millis(60));
        // The next poll sweeps and re-serves the same rows.
        let second = m.lease_prompts(&spec("live", 5000)).unwrap();
        assert_eq!(second.batch.indices, first.batch.indices);

        // Zombie chunks for the dead lease are rejected...
        let zombie = m.put_chunk(
            dead_lease,
            1,
            &[ChunkRow {
                index: first.batch.indices[0],
                tokens: vec![5],
                logps: vec![-0.5],
                finished: true,
            }],
        );
        assert!(zombie.is_err());
        // ...so the survivor's commit is the only one.
        for idx in &second.batch.indices {
            m.put_chunk(
                second.lease.unwrap(),
                1,
                &[ChunkRow {
                    index: *idx,
                    tokens: vec![7],
                    logps: vec![-0.7],
                    finished: true,
                }],
            )
            .unwrap();
        }
        assert_eq!(tq.controller("reward").ready_depth(), 2);
        let stats = m.worker_stats();
        let dead = stats.iter().find(|s| s.worker == "dead").unwrap();
        assert_eq!(dead.requeued_rows, 2);
        assert_eq!(dead.completed_rows, 0);
        let live = stats.iter().find(|s| s.worker == "live").unwrap();
        assert_eq!(live.completed_rows, 2);
    }

    #[test]
    fn closed_reply_waits_for_in_flight_rows() {
        let tq = tq_with(1);
        let m = RolloutManager::new(tq.clone());
        let reply = m.lease_prompts(&spec("a", 40)).unwrap();
        assert_eq!(reply.batch.len(), 1);
        tq.close();
        // Queue closed but a's row is in flight: b must keep polling
        // (it may inherit the row if a dies).
        let b = m.lease_prompts(&spec("b", 40)).unwrap();
        assert!(b.lease.is_none() && !b.closed);
        std::thread::sleep(Duration::from_millis(80));
        // a expired -> requeued -> b gets the row even post-close (drain).
        let b2 = m.lease_prompts(&spec("b", 5000)).unwrap();
        assert_eq!(b2.batch.len(), 1);
        m.put_chunk(
            b2.lease.unwrap(),
            0,
            &[ChunkRow {
                index: b2.batch.indices[0],
                tokens: vec![1],
                logps: vec![-0.1],
                finished: true,
            }],
        )
        .unwrap();
        // Everything committed: now the pool reports closed.
        let done = m.lease_prompts(&spec("b", 40)).unwrap();
        assert!(done.lease.is_none() && done.closed);
    }

    #[test]
    fn lease_rejects_bad_requests() {
        let m = RolloutManager::new(tq_with(1));
        assert!(m.lease_prompts(&spec("", 100)).is_err(), "empty worker");
        assert!(
            m.lease_prompts(&LeaseSpec {
                timeout_ms: 0,
                ..LeaseSpec::new("w", 0)
            })
            .is_err(),
            "zero count"
        );
        assert!(
            m.lease_prompts(&spec("w", 0)).is_err(),
            "zero ttl would livelock on requeue"
        );
        // Unknown task -> error, not panic.
        assert!(m
            .lease_prompts(&LeaseSpec {
                task: "nope".into(),
                timeout_ms: 0,
                ..LeaseSpec::new("w", 8)
            })
            .is_err());
    }

    #[test]
    fn failed_fetch_does_not_strand_rows() {
        let tq = tq_with(1);
        let m = RolloutManager::new(tq.clone());
        // Ask for a column the row does not carry: fetch fails...
        let bad = LeaseSpec {
            columns: vec![Column::Rewards],
            timeout_ms: 0,
            ..LeaseSpec::new("w", 8)
        };
        assert!(m.lease_prompts(&bad).is_err());
        // ...but the row is immediately leasable again.
        let ok = m.lease_prompts(&spec("w", 100)).unwrap();
        assert_eq!(ok.batch.len(), 1);
    }

    #[test]
    fn granted_leases_mint_unique_traces() {
        let _gate = crate::telemetry::test_enable_gate();
        crate::telemetry::set_enabled(Some(true));
        let tq = tq_with(2);
        let m = RolloutManager::new(tq.clone());
        let s = LeaseSpec {
            ttl_ms: 5000,
            timeout_ms: 0,
            ..LeaseSpec::new("w", 1)
        };
        let a = m.lease_prompts(&s).unwrap();
        let b = m.lease_prompts(&s).unwrap();
        assert_ne!(a.trace, 0);
        assert_ne!(b.trace, 0);
        assert_ne!(a.trace, b.trace, "each lease gets its own trace");
        assert!(a.trace <= crate::telemetry::TRACE_ID_MASK);
        assert_eq!(m.trace_of(a.lease.unwrap()), a.trace);
        assert_eq!(m.trace_of(b.lease.unwrap()), b.trace);
        // Telemetry off: grants stop minting entirely.
        crate::telemetry::set_enabled(Some(false));
        tq.put_row(vec![(Column::Prompts, Value::I32s(vec![9; 4]))])
            .unwrap();
        let c = m.lease_prompts(&s).unwrap();
        assert!(c.lease.is_some());
        assert_eq!(c.trace, 0);
        assert_eq!(m.trace_of(c.lease.unwrap()), 0);
        crate::telemetry::set_enabled(None);
    }

    #[test]
    fn put_chunk_refuses_to_double_commit_squatted_cells() {
        let tq = tq_with(1);
        let m = RolloutManager::new(tq.clone());
        let reply = m.lease_prompts(&spec("w", 5000)).unwrap();
        let idx = reply.batch.indices[0];
        // A foreign writer commits Responses behind the manager's back.
        tq.put(idx, Column::Responses, Value::I32s(vec![42])).unwrap();
        let res = m.put_chunk(
            reply.lease.unwrap(),
            0,
            &[ChunkRow {
                index: idx,
                tokens: vec![1],
                logps: vec![-0.1],
                finished: true,
            }],
        );
        assert!(res.is_err(), "pre-flight catches the squatted cell");
        // The row was NOT marked done, so it stays requeueable.
        assert_eq!(m.in_flight(), 1);
    }

    fn row(index: GlobalIndex, tokens: Vec<i32>, finished: bool) -> ChunkRow {
        let logps = tokens.iter().map(|&t| -(t as f32) / 10.0).collect();
        ChunkRow { index, tokens, logps, finished }
    }

    #[test]
    fn fail_lease_requeues_rows_immediately() {
        let tq = tq_with(2);
        let m = RolloutManager::new(tq);
        m.configure_fleet(FleetOptions {
            policy: RoutingPolicy::Fallback,
            ..FleetOptions::default()
        });
        let first = m.lease_prompts(&spec("w0", 30_000)).unwrap();
        let lease = first.lease.unwrap();
        assert_eq!(first.batch.len(), 2);
        // The worker's engine died: rows requeue NOW despite the 30s
        // TTL, and the report is idempotent.
        m.fail_lease(lease, "mock: injected engine fault").unwrap();
        m.fail_lease(lease, "duplicate report").unwrap();
        let second = m.lease_prompts(&spec("w1", 30_000)).unwrap();
        assert_eq!(second.batch.indices, first.batch.indices);
        // The failed lease is dead; late chunks bounce.
        let late = m.put_chunk(
            lease,
            0,
            &[row(first.batch.indices[0], vec![1], true)],
        );
        assert!(late.is_err());
        assert_eq!(m.fleet_stats().fallback_requeues, 2);
    }

    #[test]
    fn hedge_duplicates_straggler_and_commits_exactly_once() {
        let tq = tq_with(2);
        let m = RolloutManager::new(tq.clone());
        m.configure_fleet(FleetOptions {
            policy: RoutingPolicy::Hedge,
            hedge_factor: 0.0,
            hedge_min_ms: 0,
            hedge_min_samples: 1,
            ..FleetOptions::default()
        });
        let slow = m.lease_prompts(&spec("slow", 30_000)).unwrap();
        let slow_lease = slow.lease.unwrap();
        let rows = slow.batch.indices.clone();
        assert_eq!(rows.len(), 2);
        // One partial chunk seeds the chunk-interval distribution.
        m.put_chunk(slow_lease, 0, &[row(rows[0], vec![1], false)])
            .unwrap();
        std::thread::sleep(Duration::from_millis(5));
        // An idle peer polls with nothing queued: it inherits the
        // straggler's rows as a duplicate lease.
        let fast = m.lease_prompts(&spec("fast", 30_000)).unwrap();
        let fast_lease = fast.lease.unwrap();
        assert_eq!(fast.batch.indices, rows);
        assert_eq!(m.fleet_stats().hedges_issued, 1);
        // The duplicate finishes both rows first and commits them.
        for i in &rows {
            m.put_chunk(fast_lease, 1, &[row(*i, vec![7, 8], true)])
                .unwrap();
        }
        assert_eq!(tq.controller("reward").ready_depth(), 2);
        assert_eq!(
            tq.data_plane().get(rows[0], &Column::Responses),
            Some(Value::I32s(vec![7, 8]))
        );
        // The straggler's copy was revoked with the last win, so its
        // late chunk gets the recoverable lease error — and nothing
        // double-commits.
        let late =
            m.put_chunk(slow_lease, 0, &[row(rows[0], vec![2], true)]);
        assert!(late.unwrap_err().to_string().contains("lease"));
        assert_eq!(tq.controller("reward").ready_depth(), 2);
        let s = m.fleet_stats();
        assert_eq!(s.hedge_rows_won_by_duplicate, 2);
        assert_eq!(
            s.duplicated_tokens, 1,
            "straggler's discarded partial decode is accounted"
        );
        assert_eq!(m.in_flight(), 0);
    }

    /// Hedge a 2-row straggler lease: returns
    /// `(manager, tq, slow_lease, fast_lease, rows)` with the fast
    /// duplicate holding both rows.
    fn hedged_pair(
    ) -> (RolloutManager, Arc<TransferQueue>, LeaseId, LeaseId, Vec<GlobalIndex>)
    {
        let tq = tq_with(2);
        let m = RolloutManager::new(tq.clone());
        m.configure_fleet(FleetOptions {
            policy: RoutingPolicy::Hedge,
            hedge_factor: 0.0,
            hedge_min_ms: 0,
            hedge_min_samples: 1,
            ..FleetOptions::default()
        });
        let slow = m.lease_prompts(&spec("slow", 30_000)).unwrap();
        let slow_lease = slow.lease.unwrap();
        let rows = slow.batch.indices.clone();
        m.put_chunk(slow_lease, 0, &[row(rows[0], vec![1], false)])
            .unwrap();
        std::thread::sleep(Duration::from_millis(5));
        let fast = m.lease_prompts(&spec("fast", 30_000)).unwrap();
        let fast_lease = fast.lease.unwrap();
        assert_eq!(fast.batch.indices, rows);
        (m, tq, slow_lease, fast_lease, rows)
    }

    #[test]
    fn failed_commit_rolls_back_hedge_claim() {
        let (m, tq, slow_lease, fast_lease, rows) = hedged_pair();
        // The duplicate's finishing chunk is rejected by the lease
        // table (it smuggles a row outside the lease), AFTER the
        // router provisionally claimed the hedged row for it.
        let bad = m.put_chunk(
            fast_lease,
            1,
            &[
                row(rows[0], vec![7, 8], true),
                row(GlobalIndex(u64::MAX), vec![9], true),
            ],
        );
        assert!(bad.is_err());
        assert_eq!(
            tq.controller("reward").ready_depth(),
            0,
            "nothing committed"
        );
        // The claim was rolled back, so the row is NOT stranded: the
        // straggler still commits it...
        m.put_chunk(slow_lease, 0, &[row(rows[0], vec![2], true)])
            .unwrap();
        assert_eq!(
            tq.data_plane().get(rows[0], &Column::Responses),
            Some(Value::I32s(vec![1, 2]))
        );
        // ...and the duplicate's copy of it now diverts.
        m.put_chunk(fast_lease, 1, &[row(rows[0], vec![7, 8], true)])
            .unwrap();
        assert_eq!(tq.controller("reward").ready_depth(), 1);
        let s = m.fleet_stats();
        assert_eq!(s.hedge_rows_won_by_primary, 1);
        assert_eq!(s.hedge_rows_won_by_duplicate, 0);
    }

    #[test]
    fn failed_commit_leaves_hedged_row_requeueable() {
        let (m, _tq, slow_lease, fast_lease, rows) = hedged_pair();
        // Claim + commit failure on the duplicate, as above.
        assert!(m
            .put_chunk(
                fast_lease,
                1,
                &[
                    row(rows[0], vec![7, 8], true),
                    row(GlobalIndex(u64::MAX), vec![9], true),
                ],
            )
            .is_err());
        // Both sides die without ever committing the row: it must
        // requeue (the rolled-back claim is not "already committed").
        m.fail_lease(slow_lease, "test: straggler died").unwrap();
        m.fail_lease(fast_lease, "test: duplicate died").unwrap();
        let next = m.lease_prompts(&spec("heir", 30_000)).unwrap();
        assert!(
            next.batch.indices.contains(&rows[0]),
            "hedged row requeued after both deaths: {:?}",
            next.batch.indices
        );
    }

    #[test]
    fn squatted_duplicated_row_drops_instead_of_erroring() {
        let (m, tq, slow_lease, fast_lease, rows) = hedged_pair();
        // A commit landed outside the pair (the duplicate-grant race:
        // the row's cells exist but no participant won it).
        tq.put(rows[0], Column::Responses, Value::I32s(vec![42]))
            .unwrap();
        // Neither side errors out — the worker loop treats non-lease
        // errors as fatal, and this is not the worker's fault. Both
        // copies divert.
        m.put_chunk(fast_lease, 1, &[row(rows[0], vec![7], true)])
            .unwrap();
        m.put_chunk(slow_lease, 0, &[row(rows[0], vec![2], true)])
            .unwrap();
        assert_eq!(
            tq.data_plane().get(rows[0], &Column::Responses),
            Some(Value::I32s(vec![42])),
            "the squatting commit is untouched"
        );
        // The second row is uncontested for the pair and still races
        // normally.
        m.put_chunk(fast_lease, 1, &[row(rows[1], vec![5], true)])
            .unwrap();
        assert_eq!(m.in_flight(), 0);
    }

    #[test]
    fn empty_queue_poll_is_not_a_deferral() {
        let tq = tq_with(2);
        let m = RolloutManager::new(tq.clone());
        let first = m.lease_prompts(&spec("loaded", 30_000)).unwrap();
        assert_eq!(first.batch.len(), 2);
        assert!(m.lease_prompts(&spec("idle", 30_000)).unwrap().lease.is_none());
        // The loaded worker polls an EMPTY queue: nothing to defer, so
        // nothing is counted (and a long-poll would not be cut short).
        assert!(m
            .lease_prompts(&spec("loaded", 30_000))
            .unwrap()
            .lease
            .is_none());
        assert_eq!(m.fleet_stats().lb_deferrals, 0);
        // With a row actually queued the deferral fires as before.
        tq.put_row(vec![(Column::Prompts, Value::I32s(vec![9; 4]))])
            .unwrap();
        assert!(m
            .lease_prompts(&spec("loaded", 30_000))
            .unwrap()
            .lease
            .is_none());
        assert_eq!(m.fleet_stats().lb_deferrals, 1);
    }

    #[test]
    fn mirror_duplicates_and_detects_divergence() {
        let tq = tq_with(1);
        let m = RolloutManager::new(tq.clone());
        m.configure_fleet(FleetOptions {
            policy: RoutingPolicy::Mirror,
            mirror_fanout: 2,
            ..FleetOptions::default()
        });
        let a = m.lease_prompts(&spec("a", 30_000)).unwrap();
        let a_lease = a.lease.unwrap();
        let idx0 = a.batch.indices[0];
        let b = m.lease_prompts(&spec("b", 30_000)).unwrap();
        let b_lease = b.lease.unwrap();
        assert_eq!(b.batch.indices, vec![idx0]);
        assert_eq!(m.fleet_stats().mirrors_issued, 1);
        // Primary commits; the mirror's differing copy is compared
        // against the committed tokens, never committed itself.
        m.put_chunk(a_lease, 1, &[row(idx0, vec![1, 2], true)]).unwrap();
        m.put_chunk(b_lease, 1, &[row(idx0, vec![1, 9], true)]).unwrap();
        assert_eq!(
            tq.data_plane().get(idx0, &Column::Responses),
            Some(Value::I32s(vec![1, 2]))
        );
        assert_eq!(tq.controller("reward").ready_depth(), 1);
        let s = m.fleet_stats();
        assert_eq!(s.mirror_divergences, 1);
        assert_eq!(s.mirror_matches, 0);
        assert_eq!(m.in_flight(), 0);
    }

    #[test]
    fn load_balance_defers_loaded_worker_for_idle_peer() {
        let tq = tq_with(2);
        let m = RolloutManager::new(tq.clone());
        // Default policy is load-balance.
        let first = m.lease_prompts(&spec("loaded", 30_000)).unwrap();
        assert_eq!(first.batch.len(), 2);
        // The idle peer announces itself with an (empty) poll.
        assert!(m.lease_prompts(&spec("idle", 30_000)).unwrap().lease.is_none());
        tq.put_row(vec![(Column::Prompts, Value::I32s(vec![9; 4]))])
            .unwrap();
        // The loaded worker's poll is deferred in favor of the idler...
        let deferred = m.lease_prompts(&spec("loaded", 30_000)).unwrap();
        assert!(deferred.lease.is_none());
        assert!(m.fleet_stats().lb_deferrals >= 1);
        // ...who picks the row up on its next poll.
        let got = m.lease_prompts(&spec("idle", 30_000)).unwrap();
        assert_eq!(got.batch.len(), 1);
    }

    #[test]
    fn worker_stats_carry_engine_specs() {
        let tq = tq_with(1);
        let m = RolloutManager::new(tq);
        let eng = EngineSpec::new("mock", 8, 16, 48)
            .with_tags(vec!["fast-cheap".into()]);
        let s = LeaseSpec {
            ttl_ms: 5000,
            timeout_ms: 0,
            engine: Some(eng.clone()),
            ..LeaseSpec::new("w0", 8)
        };
        m.lease_prompts(&s).unwrap();
        let stats = m.worker_stats();
        let w = stats.iter().find(|w| w.worker == "w0").unwrap();
        let got = w.engine.as_ref().unwrap();
        assert_eq!(got.kind, "mock");
        assert_eq!(got.tags, vec!["fast-cheap"]);
        // Statically-registered engines surface in the fleet snapshot.
        m.register_engine("xla-0", EngineSpec::new("xla", 8, 16, 48));
        let fs = m.fleet_stats();
        assert!(fs
            .engines
            .iter()
            .any(|e| e.worker == "xla-0" && e.source == "config"));
    }
}
