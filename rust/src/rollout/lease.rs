//! Rollout lease table: the bookkeeping heart of elastic rollout.
//!
//! Every batch of prompt rows handed to a worker travels under a *lease*
//! — an id, an owner, a source task, an expiry, and the partial-row
//! state (tokens/logps accumulated so far) for each row. Workers keep a
//! lease alive by streaming chunks (`put_chunk` is an implicit
//! heartbeat) or renewing explicitly; a lease that misses its deadline
//! is swept, and its *incomplete* rows are requeued — exactly once,
//! because sweep and append are mutually exclusive under the table lock
//! and a swept lease id is dead forever (a zombie worker's late chunks
//! are rejected, never committed).
//!
//! Since the consumer-lease generalization, lease lifecycle (ids, TTLs,
//! expiry sweep, exactly-once revocation) lives in the shared
//! [`LeaseRegistry`] on the control plane — the same mechanism that
//! makes generic `get_batch` consumers crash-safe. This table is the
//! rollout-specific layer on top: per-row decode buffers (tokens/logps)
//! and cumulative per-worker statistics.

use std::collections::{HashMap, HashSet};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::fleet::EngineSpec;
use crate::transfer_queue::{
    GlobalIndex, LeaseAccounting, LeaseRegistry, RevokedLease,
};

use super::manager::ChunkRow;

pub use crate::transfer_queue::LeaseId;

/// Per-worker statistics (the `worker_stats` verb payload).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerStat {
    /// Worker name (the lease owner).
    pub worker: String,
    /// Live leases currently held.
    pub active_leases: usize,
    /// Leased rows not yet finished (the load-balancing signal).
    pub in_flight_rows: usize,
    /// Rows generated to completion and committed.
    pub completed_rows: u64,
    /// Response tokens streamed (finished or not).
    pub generated_tokens: u64,
    /// Rows taken from this worker's expired or failed leases and
    /// handed back for requeue.
    pub requeued_rows: u64,
    /// Capability report of the worker's engine, when known — attached
    /// by the fleet registry, not tracked here. Old workers that never
    /// report a spec simply leave this `None`.
    pub engine: Option<EngineSpec>,
}

/// Partial-row decode state: what a worker has streamed for one leased
/// row so far.
#[derive(Default)]
struct RowBuf {
    tokens: Vec<i32>,
    logps: Vec<f32>,
}

#[derive(Default)]
struct WorkerInfo {
    completed: u64,
    tokens: u64,
    requeued: u64,
}

/// Thread-safe rollout lease registry: [`LeaseRegistry`] lifecycle plus
/// partial-row buffers and per-worker stats.
#[derive(Default)]
pub struct LeaseTable {
    registry: LeaseRegistry<RowBuf>,
    workers: Mutex<HashMap<String, WorkerInfo>>,
}

impl LeaseTable {
    /// An empty table.
    pub fn new() -> Self {
        Self::default()
    }

    /// Keep the per-worker stats registry bounded: once this many
    /// distinct worker names have been seen, registering a new one
    /// evicts the cumulative stats of workers with no live lease
    /// (elastic pools churn through `worker-<pid>` names forever).
    const MAX_WORKER_STATS: usize = 1024;

    /// Grant a new lease on `indices` (popped from `task`) to `worker`.
    pub fn grant(
        &self,
        worker: &str,
        task: &str,
        indices: &[GlobalIndex],
        ttl: Duration,
    ) -> LeaseId {
        {
            let mut w = self.workers.lock().unwrap();
            if w.len() >= Self::MAX_WORKER_STATS
                && !w.contains_key(worker)
            {
                let live = self.registry.live_owners();
                w.retain(|name, _| live.contains(name));
            }
            w.entry(worker.to_string()).or_default();
        }
        self.registry.grant(worker, task, indices, ttl)
    }

    /// Heartbeat: extend a live lease. `ttl = None` reuses the lease's
    /// own TTL. Unknown ids (including swept ones) are an error — the
    /// worker must drop its in-flight batch and re-lease.
    pub fn renew(&self, id: LeaseId, ttl: Option<Duration>) -> Result<()> {
        self.registry.renew(id, ttl)
    }

    /// Atomically append a batch of chunks to a live lease — one lock
    /// acquisition, so a sweep can never interleave mid-batch, and the
    /// whole batch is validated before any row is touched: a rejected
    /// request leaves no partial state (what the client observes as an
    /// error matches what the server applied — nothing). Implicit
    /// heartbeat. Returns `(index, tokens, logps)` for each row this
    /// batch finished, in input order; a lease whose rows are all done
    /// is retired automatically.
    #[allow(clippy::type_complexity)]
    pub fn append_rows(
        &self,
        id: LeaseId,
        rows: &[ChunkRow],
    ) -> Result<Vec<(GlobalIndex, Vec<i32>, Vec<f32>)>> {
        let (worker, out, tokens_total, finished_total) =
            self.registry.with_rows(id, |owner, table| {
                // Validate everything first — no partial application.
                let mut seen = HashSet::new();
                for r in rows {
                    if r.tokens.len() != r.logps.len() {
                        bail!(
                            "chunk for {}: {} tokens but {} logps",
                            r.index,
                            r.tokens.len(),
                            r.logps.len()
                        );
                    }
                    if !seen.insert(r.index) {
                        bail!(
                            "row {} appears twice in one chunk batch",
                            r.index
                        );
                    }
                    let Some(row) = table.get(&r.index) else {
                        bail!("row {} is not part of lease {id}", r.index);
                    };
                    if row.done {
                        bail!(
                            "row {} already finished under lease {id}",
                            r.index
                        );
                    }
                    if r.finished
                        && row.state.tokens.is_empty()
                        && r.tokens.is_empty()
                    {
                        bail!("row {} finished with zero tokens", r.index);
                    }
                }
                // Apply.
                let mut out = Vec::new();
                let mut tokens_total = 0u64;
                let mut finished_total = 0u64;
                for r in rows {
                    let row = table.get_mut(&r.index).unwrap();
                    row.state.tokens.extend_from_slice(&r.tokens);
                    row.state.logps.extend_from_slice(&r.logps);
                    tokens_total += r.tokens.len() as u64;
                    if r.finished {
                        row.done = true;
                        finished_total += 1;
                        out.push((
                            r.index,
                            std::mem::take(&mut row.state.tokens),
                            std::mem::take(&mut row.state.logps),
                        ));
                    }
                }
                Ok((owner.to_string(), out, tokens_total, finished_total))
            })?;
        let mut w = self.workers.lock().unwrap();
        let info = w.entry(worker).or_default();
        info.tokens += tokens_total;
        info.completed += finished_total;
        Ok(out)
    }

    /// Single-row convenience over [`LeaseTable::append_rows`]. Returns
    /// the accumulated `(tokens, logps)` when `finished` completes the
    /// row, `None` on a partial append.
    pub fn append(
        &self,
        id: LeaseId,
        index: GlobalIndex,
        tokens: &[i32],
        logps: &[f32],
        finished: bool,
    ) -> Result<Option<(Vec<i32>, Vec<f32>)>> {
        let row = ChunkRow {
            index,
            tokens: tokens.to_vec(),
            logps: logps.to_vec(),
            finished,
        };
        let mut out = self.append_rows(id, std::slice::from_ref(&row))?;
        Ok(out.pop().map(|(_, t, l)| (t, l)))
    }

    /// Remove expired leases; returns each revoked lease (id, owner,
    /// source task, incomplete rows). Completed rows were already
    /// committed and are left alone; which of the incomplete rows
    /// actually requeue is the caller's call — under hedge/mirror
    /// routing a row may be covered by a live duplicate.
    pub fn sweep_expired(&self) -> Vec<RevokedLease> {
        let swept = self.registry.sweep_expired();
        if !swept.is_empty() {
            let mut w = self.workers.lock().unwrap();
            for lease in &swept {
                let info = w.entry(lease.owner.clone()).or_default();
                info.requeued += lease.rows.len() as u64;
            }
        }
        swept
    }

    /// Force a live lease out of the table (the `fail_lease` verb — the
    /// worker's engine errored and the rows should requeue now rather
    /// than wait out the TTL). `None` when the id is unknown: already
    /// retired, swept, or never granted.
    pub fn revoke(&self, id: LeaseId) -> Option<RevokedLease> {
        let revoked = self.registry.revoke(id)?;
        let mut w = self.workers.lock().unwrap();
        w.entry(revoked.owner.clone()).or_default().requeued +=
            revoked.rows.len() as u64;
        drop(w);
        Some(revoked)
    }

    /// Whether `id` is still live (not retired, revoked, or swept).
    pub fn is_live(&self, id: LeaseId) -> bool {
        self.registry.is_live(id)
    }

    /// Not-yet-finished rows of a live lease, sorted — what a hedge
    /// duplicates to a second engine. `None` when the id is unknown.
    pub fn undone_rows(&self, id: LeaseId) -> Option<Vec<GlobalIndex>> {
        self.registry.undone_rows(id)
    }

    /// Discard one row of a live lease: mark it done *without* counting
    /// it as completed and hand back whatever partial decode had
    /// accumulated (so the caller can account discarded work). Used to
    /// retire the losing side of a hedged/mirrored row. Absorbs unknown
    /// lease, unknown row, and already-done row as `None` — discard
    /// races lease death by design. Retires the lease when this was its
    /// last undone row.
    pub fn take_row_discard(
        &self,
        id: LeaseId,
        index: GlobalIndex,
    ) -> Option<(Vec<i32>, Vec<f32>)> {
        self.registry
            .with_rows(id, |_, table| {
                let Some(row) = table.get_mut(&index) else {
                    return Ok(None);
                };
                if row.done {
                    return Ok(None);
                }
                row.done = true;
                Ok(Some((
                    std::mem::take(&mut row.state.tokens),
                    std::mem::take(&mut row.state.logps),
                )))
            })
            .ok()
            .flatten()
    }

    /// Per-owner `(live leases, unfinished rows)` — the load-balancing
    /// input for the fleet router.
    pub fn owner_load(&self) -> HashMap<String, (usize, usize)> {
        self.registry.owner_load()
    }

    /// Leased rows not yet finished, across all live leases.
    pub fn in_flight(&self) -> usize {
        self.registry.in_flight()
    }

    /// Earliest lease expiry (`None` when no lease is live) — the wake
    /// deadline for an expiry-driven sweeper.
    pub fn next_expiry(&self) -> Option<std::time::Instant> {
        self.registry.next_expiry()
    }

    /// Install the registry's expiry re-arm hook (called on grant/renew
    /// so a sweeper can re-arm its timer instead of polling).
    pub fn set_expiry_hook(&self, f: crate::transfer_queue::WakeFn) {
        self.registry.set_expiry_hook(f);
    }

    /// Leased-and-unfinished rows popped from `task` (drain barrier for
    /// one prompt stream, and the per-task leased stat).
    pub fn in_flight_for(&self, task: &str) -> usize {
        self.registry.in_flight_for(task)
    }

    /// Per-task cumulative lease books (see
    /// [`crate::transfer_queue::LeaseAccounting`]), snapshotted under
    /// one registry lock acquisition.
    pub fn accounting(&self) -> HashMap<String, LeaseAccounting> {
        self.registry.accounting()
    }

    /// Per-worker snapshot, sorted by worker name.
    pub fn stats(&self) -> Vec<WorkerStat> {
        let load = self.registry.owner_load();
        let w = self.workers.lock().unwrap();
        let mut out: Vec<WorkerStat> = w
            .iter()
            .map(|(name, info)| {
                let (leases, in_flight) =
                    load.get(name).copied().unwrap_or((0, 0));
                WorkerStat {
                    worker: name.clone(),
                    active_leases: leases,
                    in_flight_rows: in_flight,
                    completed_rows: info.completed,
                    generated_tokens: info.tokens,
                    requeued_rows: info.requeued,
                    engine: None,
                }
            })
            .collect();
        out.sort_by(|a, b| a.worker.cmp(&b.worker));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idx(n: u64) -> GlobalIndex {
        GlobalIndex(n)
    }

    fn chunk(n: u64, tokens: Vec<i32>, finished: bool) -> ChunkRow {
        let logps = tokens.iter().map(|&t| -(t as f32) / 10.0).collect();
        ChunkRow { index: idx(n), tokens, logps, finished }
    }

    #[test]
    fn append_accumulates_and_commits_on_finish() {
        let t = LeaseTable::new();
        let id =
            t.grant("w", "rollout", &[idx(0), idx(1)], Duration::from_secs(5));
        assert!(t
            .append(id, idx(0), &[1, 2], &[-0.1, -0.2], false)
            .unwrap()
            .is_none());
        let (tokens, logps) = t
            .append(id, idx(0), &[3], &[-0.3], true)
            .unwrap()
            .unwrap();
        assert_eq!(tokens, vec![1, 2, 3]);
        assert_eq!(logps, vec![-0.1, -0.2, -0.3]);
        // finished row cannot be appended to again
        assert!(t.append(id, idx(0), &[9], &[-0.9], true).is_err());
        assert_eq!(t.in_flight(), 1);
        assert_eq!(t.in_flight_for("rollout"), 1);
        assert_eq!(t.in_flight_for("other"), 0);
        // finishing the last row retires the lease
        t.append(id, idx(1), &[7], &[-0.7], true).unwrap().unwrap();
        assert!(t.renew(id, None).is_err(), "lease retired");
        let stats = t.stats();
        assert_eq!(stats[0].completed_rows, 2);
        assert_eq!(stats[0].generated_tokens, 4);
        assert_eq!(stats[0].active_leases, 0);
    }

    #[test]
    fn append_rows_is_all_or_nothing() {
        let t = LeaseTable::new();
        let id =
            t.grant("w", "rollout", &[idx(0), idx(1)], Duration::from_secs(5));
        // Second row is invalid (not part of the lease): the whole batch
        // must be rejected with no partial state.
        let bad = t.append_rows(
            id,
            &[chunk(0, vec![1, 2], true), chunk(9, vec![3], false)],
        );
        assert!(bad.is_err());
        assert_eq!(t.in_flight(), 2, "row 0 not marked done");
        // Duplicate index in one batch is rejected up front too.
        assert!(t
            .append_rows(
                id,
                &[chunk(0, vec![1], false), chunk(0, vec![2], true)],
            )
            .is_err());
        // The valid batch then commits both rows atomically.
        let done = t
            .append_rows(
                id,
                &[chunk(0, vec![1, 2], true), chunk(1, vec![3], true)],
            )
            .unwrap();
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].0, idx(0));
        assert_eq!(done[0].1, vec![1, 2]);
        assert_eq!(done[1].0, idx(1));
        assert_eq!(t.in_flight(), 0);
    }

    #[test]
    fn append_guards_bad_input() {
        let t = LeaseTable::new();
        let id = t.grant("w", "rollout", &[idx(0)], Duration::from_secs(5));
        assert!(t.append(id, idx(0), &[1], &[], false).is_err(), "len");
        assert!(t.append(id, idx(9), &[1], &[-0.1], false).is_err());
        assert!(t.append(id + 1, idx(0), &[1], &[-0.1], false).is_err());
        assert!(
            t.append(id, idx(0), &[], &[], true).is_err(),
            "empty finish"
        );
    }

    #[test]
    fn sweep_requeues_only_incomplete_rows_exactly_once() {
        let t = LeaseTable::new();
        let id = t.grant(
            "w",
            "rollout",
            &[idx(3), idx(4), idx(5)],
            Duration::from_millis(30),
        );
        t.append(id, idx(3), &[1], &[-0.1], true).unwrap();
        std::thread::sleep(Duration::from_millis(60));
        let lost = t.sweep_expired();
        assert_eq!(lost.len(), 1);
        assert_eq!(lost[0].id, id);
        assert_eq!(lost[0].task, "rollout");
        assert_eq!(
            lost[0].rows,
            vec![idx(4), idx(5)],
            "finished row not requeued"
        );
        assert!(t.sweep_expired().is_empty(), "second sweep finds nothing");
        // the zombie's late chunk is rejected, never committed
        assert!(t.append(id, idx(4), &[2], &[-0.2], true).is_err());
        let stats = t.stats();
        assert_eq!(stats[0].requeued_rows, 2);
        assert_eq!(stats[0].completed_rows, 1);
    }

    #[test]
    fn heartbeats_keep_leases_alive() {
        let t = LeaseTable::new();
        let id = t.grant("w", "rollout", &[idx(0)], Duration::from_millis(50));
        for _ in 0..4 {
            std::thread::sleep(Duration::from_millis(25));
            t.renew(id, None).unwrap();
            assert!(t.sweep_expired().is_empty());
        }
        // appends heartbeat too
        std::thread::sleep(Duration::from_millis(25));
        t.append(id, idx(0), &[1], &[-0.5], false).unwrap();
        assert!(t.sweep_expired().is_empty());
        assert_eq!(t.in_flight(), 1);
    }

    #[test]
    fn take_row_discard_skips_completed_and_retires_lease() {
        let t = LeaseTable::new();
        let id =
            t.grant("w", "rollout", &[idx(0), idx(1)], Duration::from_secs(5));
        t.append(id, idx(0), &[1, 2], &[-0.1, -0.2], false).unwrap();
        // Discard hands back the partial decode without counting it.
        let (tokens, logps) = t.take_row_discard(id, idx(0)).unwrap();
        assert_eq!(tokens, vec![1, 2]);
        assert_eq!(logps.len(), 2);
        assert!(t.take_row_discard(id, idx(0)).is_none(), "already done");
        assert!(t.take_row_discard(id, idx(9)).is_none(), "not in lease");
        assert_eq!(t.undone_rows(id), Some(vec![idx(1)]));
        // Finishing the last real row then retires the lease; nothing
        // counts as completed for the discarded one.
        t.append(id, idx(1), &[7], &[-0.7], true).unwrap().unwrap();
        assert!(!t.is_live(id));
        assert!(t.take_row_discard(id, idx(1)).is_none(), "dead lease");
        assert_eq!(t.stats()[0].completed_rows, 1);
    }

    #[test]
    fn revoke_counts_requeued_rows() {
        let t = LeaseTable::new();
        let id =
            t.grant("w", "rollout", &[idx(0), idx(1)], Duration::from_secs(5));
        let revoked = t.revoke(id).unwrap();
        assert_eq!(revoked.rows, vec![idx(0), idx(1)]);
        assert!(t.revoke(id).is_none(), "second revoke is a no-op");
        assert!(!t.is_live(id));
        assert_eq!(t.stats()[0].requeued_rows, 2);
    }

    #[test]
    fn stats_track_load_per_worker() {
        let t = LeaseTable::new();
        t.grant("a", "rollout", &[idx(0), idx(1)], Duration::from_secs(5));
        t.grant("b", "rollout", &[idx(2)], Duration::from_secs(5));
        let stats = t.stats();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].worker, "a");
        assert_eq!(stats[0].in_flight_rows, 2);
        assert_eq!(stats[1].worker, "b");
        assert_eq!(stats[1].in_flight_rows, 1);
    }
}
