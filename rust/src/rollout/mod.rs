//! Elastic streaming rollout subsystem.
//!
//! A new layer between the service API and the engines: prompt groups
//! are *leased* to an elastic pool of rollout workers (local threads or
//! remote processes attached over TCP), generations stream back in
//! bounded chunks, and crashed or straggling workers lose their leases —
//! whose rows are requeued exactly once to whichever peer polls next.
//!
//! ```text
//!            coordinator side                         worker side
//!  ┌───────────────────────────────┐       ┌──────────────────────────┐
//!  │ RolloutManager                │◀──────│ run_worker(ServiceClient)│
//!  │  ├ LeaseTable (partial rows,  │ lease │  ├ PolicyEngine::        │
//!  │  │  heartbeats, expiry)       │ chunk │  │   begin_generate/step │
//!  │  └ rollout Controller         │ renew │  └ subscribe_weights at  │
//!  │     (exactly-once pop/requeue)│ stats │     chunk boundaries     │
//!  └──────────────┬────────────────┘       └──────────────────────────┘
//!                 ▼ per-row commit (Responses + OldLogp + version)
//!            TransferQueue  → downstream stages start on finished rows
//!                             while the long tail is still decoding
//! ```
//!
//! * [`manager`] — [`RolloutManager`]: serves the `lease_prompts` /
//!   `put_chunk` / `renew_lease` / `worker_stats` verbs.
//! * [`lease`] — [`LeaseTable`]: lease ids, TTLs, partial-row state,
//!   exactly-once requeue on expiry.
//! * [`worker`] — [`run_worker`]: the transport-agnostic worker loop
//!   (used by the Trainer's local pool and `asyncflow rollout-worker`).

pub mod lease;
pub mod manager;
pub mod worker;

pub use lease::{LeaseId, LeaseTable, WorkerStat};
pub use manager::{ChunkRow, LeaseReply, LeaseSpec, RolloutManager};
pub use worker::{run_worker, WorkerOptions, WorkerReport};
