//! Client-side rollout worker: lease → chunked decode → streamed chunks.
//!
//! A worker drives any [`PolicyEngine`] through the incremental decode
//! API and talks to the coordinator purely through [`ServiceClient`]
//! verbs, so the same loop runs in-process (the Trainer's local pool),
//! or in another process attached over TCP (`asyncflow rollout-worker
//! --connect host:port`) — the elastic part of the subsystem. Weight
//! refreshes happen at *chunk* boundaries through a delta-aware
//! [`WeightMirror`]: long-poll the manifest, pull only stale tensors
//! (binary, from the storage-unit fan-out tier when attached), share
//! the rest by `Arc` (the delayed parameter update of §4.2.2 at
//! sub-batch granularity), still bounded by the IterationGate's
//! staleness control on the feeder side.
//!
//! Liveness vs crash detection: a background heartbeat thread renews the
//! active lease every `ttl_ms / 3`, so the TTL bounds how fast a *dead*
//! worker's rows are requeued — it does NOT bound how long a chunk (or
//! the first buffered whole-sequence decode of a fixed-geometry backend)
//! may take. The heartbeat dies with the worker, which is exactly the
//! crash signal the coordinator keys on. The heartbeat shares this
//! worker's `ServiceClient`; on a pipelined transport a parked
//! long-poll (`lease_prompts`, `subscribe_weights_meta`) is just
//! another in-flight `seq` on the same connection, and on classic
//! one-in-flight transports the client routes those verbs over a
//! dedicated sibling connection — either way a parked lease poll can
//! never delay a heartbeat or a chunk upload behind the transport's
//! stream mutex.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::coordinator::Timeline;
use crate::fleet::EngineSpec;
use crate::metrics::Registry;
use crate::runtime::{PolicyEngine, Sampler};
use crate::service::ServiceClient;
use crate::transfer_queue::Column;
use crate::weights::WeightMirror;

use super::lease::LeaseId;
use super::manager::{ChunkRow, LeaseSpec};

/// Tuning knobs for one rollout worker.
#[derive(Debug, Clone)]
pub struct WorkerOptions {
    /// Worker name (lease owner; timeline row; stats key).
    pub name: String,
    /// Task whose controller feeds this worker.
    pub task: String,
    /// Rows requested per lease (clamped to the engine batch).
    pub lease_rows: usize,
    /// Decode chunk size: tokens per sequence per `step`.
    pub chunk_tokens: usize,
    /// Lease TTL — how long after the worker's last heartbeat the
    /// coordinator requeues its in-flight rows. A background thread
    /// heartbeats at `ttl_ms / 3`, so this bounds crash detection
    /// latency, not chunk duration.
    pub ttl_ms: u64,
    /// Server-side long-poll budget per `lease_prompts` when the pool
    /// is empty (0 = pure poll with a 1ms client-side backoff).
    pub poll_ms: u64,
    pub eos: i32,
    pub pad: i32,
    /// Capability tags attached to this worker's engine spec
    /// (`--engine-tags fast-cheap,mock`): the fleet registry derives
    /// the speed class from them and `info --connect` displays them.
    pub engine_tags: Vec<String>,
}

impl WorkerOptions {
    pub fn new(name: impl Into<String>) -> Self {
        WorkerOptions {
            name: name.into(),
            task: "rollout".into(),
            lease_rows: usize::MAX, // clamped to the engine batch
            chunk_tokens: 8,
            ttl_ms: 1000,
            poll_ms: 50,
            eos: crate::data::EOS,
            pad: crate::data::PAD,
            engine_tags: Vec::new(),
        }
    }
}

/// What one worker did over its lifetime.
#[derive(Debug, Clone, Default)]
pub struct WorkerReport {
    /// Rows generated to completion and accepted by the coordinator.
    pub samples: u64,
    /// Response tokens accepted (across finished and partial rows).
    pub tokens: u64,
    /// Chunk round-trips made.
    pub chunks: u64,
    /// Weight snapshots swapped in at chunk boundaries.
    pub weight_swaps: u64,
    /// Leases lost to expiry mid-generation (work abandoned + requeued).
    pub leases_lost: u64,
    /// Engine faults survived: the batch was abandoned, the lease
    /// failed over to a peer (`fail_lease`), and the loop carried on.
    pub engine_errors: u64,
}

fn swap_weights(
    client: &ServiceClient,
    engine: &mut dyn PolicyEngine,
    mirror: &mut WeightMirror,
    metrics: Option<&Registry>,
    report: &mut WorkerReport,
) -> Result<()> {
    if let Some(latest) = mirror.sync(client, 0)? {
        engine.set_params(latest);
        report.weight_swaps += 1;
        if let Some(m) = metrics {
            m.inc("weight_swaps", 1);
        }
    }
    Ok(())
}

/// Recover from an engine fault mid-batch: report the lease as failed
/// so the coordinator requeues the rows *immediately* (the fallback
/// routing path) instead of letting them ride out the TTL, clear the
/// decode state, and count the event. The wire report is best-effort —
/// if the coordinator is unreachable too, the TTL sweep remains the
/// backstop.
#[allow(clippy::too_many_arguments)]
fn engine_fault(
    client: &ServiceClient,
    engine: &mut dyn PolicyEngine,
    opts: &WorkerOptions,
    metrics: Option<&Registry>,
    hb_lease: &AtomicU64,
    lease: LeaseId,
    err: &anyhow::Error,
    report: &mut WorkerReport,
) {
    report.engine_errors += 1;
    if let Some(m) = metrics {
        m.inc("engine_errors", 1);
    }
    crate::log_warn!(
        &opts.name,
        "engine fault mid-generation ({err:#}); failing lease {lease} \
         over to the pool"
    );
    hb_lease.store(0, Ordering::SeqCst);
    let _ = engine.finish_generate();
    let _ = client.fail_lease(lease, &format!("{err:#}"));
}

/// Run the worker loop until the prompt stream closes or `abort` trips.
///
/// Losing a lease (expiry while a chunk was in flight) is *recoverable*:
/// the coordinator has already requeued the rows, so the worker abandons
/// the batch and leases afresh. Transport/service failures on the lease
/// path propagate as errors.
pub fn run_worker(
    client: &ServiceClient,
    engine: &mut dyn PolicyEngine,
    sampler: &mut Sampler,
    opts: &WorkerOptions,
    metrics: Option<&Registry>,
    timeline: Option<&Timeline>,
    abort: &dyn Fn() -> bool,
) -> Result<WorkerReport> {
    // Heartbeat thread: renews whatever lease id is currently active
    // (0 = none). Keeps arbitrarily long decodes alive; dies with us.
    let hb_lease = Arc::new(AtomicU64::new(0));
    let hb_stop = Arc::new(AtomicBool::new(false));
    let heartbeat = {
        let client = client.clone();
        let lease = hb_lease.clone();
        let stop = hb_stop.clone();
        // Renew at ttl/3 (the documented cadence), but sleep in short
        // slices so worker shutdown never waits a full tick.
        let tick = Duration::from_millis((opts.ttl_ms / 3).max(1));
        std::thread::spawn(move || loop {
            let mut slept = Duration::ZERO;
            while slept < tick {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                let slice = (tick - slept).min(Duration::from_millis(20));
                std::thread::sleep(slice);
                slept += slice;
            }
            let id = lease.load(Ordering::SeqCst);
            if id != 0 {
                // A failed renew means the lease was swept; the main
                // loop learns that from its next put_chunk. Heartbeats
                // go out as a fire-and-forget burst — one write on a
                // pipelined transport.
                let _ = client.burst().renew_lease(id, 0).send();
            }
        })
    };
    let result = run_worker_inner(
        client, engine, sampler, opts, metrics, timeline, abort, &hb_lease,
    );
    hb_stop.store(true, Ordering::SeqCst);
    hb_lease.store(0, Ordering::SeqCst);
    let _ = heartbeat.join();
    // Hand our span log to the coordinator so `asyncflow trace` can
    // merge this worker's timeline (best-effort; no-op when disabled).
    client.push_telemetry(&opts.name);
    if let Ok(r) = &result {
        crate::log_debug!(
            &opts.name,
            "worker done: {} samples, {} tokens, {} chunks, {} swaps, \
             {} leases lost, {} engine faults",
            r.samples,
            r.tokens,
            r.chunks,
            r.weight_swaps,
            r.leases_lost,
            r.engine_errors
        );
    }
    result
}

#[allow(clippy::too_many_arguments)]
fn run_worker_inner(
    client: &ServiceClient,
    engine: &mut dyn PolicyEngine,
    sampler: &mut Sampler,
    opts: &WorkerOptions,
    metrics: Option<&Registry>,
    timeline: Option<&Timeline>,
    abort: &dyn Fn() -> bool,
    hb_lease: &AtomicU64,
) -> Result<WorkerReport> {
    let mut report = WorkerReport::default();
    // Delta-aware weight sync: the mirror starts at the engine's
    // version, so only genuinely newer publishes trigger a swap.
    let mut mirror = WeightMirror::new(opts.name.clone());
    mirror.assume_version(engine.params_version());
    let chunk = opts.chunk_tokens.max(1);
    let spec = LeaseSpec {
        task: opts.task.clone(),
        worker: opts.name.clone(),
        count: opts.lease_rows.clamp(1, engine.batch_size()),
        ttl_ms: opts.ttl_ms,
        timeout_ms: opts.poll_ms,
        columns: vec![Column::Prompts],
        // Capability report rides every poll: the coordinator's fleet
        // registry learns what this engine is (and can route around or
        // hedge onto it).
        engine: Some(EngineSpec::of_engine(
            &*engine,
            opts.engine_tags.clone(),
        )),
    };
    // An engine fault (`begin_generate`/`step` erroring) is survivable:
    // fail the lease so the rows requeue immediately, then keep
    // serving. Only this many faults in a row are — a permanently
    // broken engine must fail loudly, not spin.
    const MAX_CONSECUTIVE_ENGINE_FAULTS: u32 = 3;
    let mut consecutive_faults = 0u32;
    'outer: while !abort() {
        // Delayed parameter update between leases...
        swap_weights(client, engine, &mut mirror, metrics, &mut report)?;
        let reply = client.lease_prompts(&spec)?;
        let Some(lease) = reply.lease else {
            if reply.closed {
                break;
            }
            if spec.timeout_ms == 0 {
                // Pure-poll mode: back off so the loop never spins hot.
                std::thread::sleep(Duration::from_millis(1));
            }
            continue;
        };
        hb_lease.store(lease, Ordering::SeqCst);
        // Adopt the lease's trace id: every chunk upload (and the
        // data-plane writes it triggers, all the way to remote storage
        // units) now carries the trace minted at the grant.
        let _trace_scope = crate::telemetry::scoped_trace(reply.trace);
        let gen_span_t0 = crate::telemetry::now_us();
        let batch = reply.batch;
        let mut prompts = Vec::with_capacity(batch.len());
        for row in &batch.rows {
            let p = row
                .first()
                .and_then(|v| v.as_i32s())
                .ok_or_else(|| anyhow!("leased row lacks a prompt"))?;
            prompts.push(p.to_vec());
        }
        let t0 = timeline.map(|t| t.now());
        let gen_version = engine.params_version();
        if let Err(e) =
            engine.begin_generate(&prompts, sampler, opts.eos, opts.pad)
        {
            engine_fault(
                client, engine, opts, metrics, hb_lease, lease, &e,
                &mut report,
            );
            consecutive_faults += 1;
            if consecutive_faults >= MAX_CONSECUTIVE_ENGINE_FAULTS {
                return Err(e);
            }
            continue 'outer;
        }
        loop {
            let step = match engine.step(chunk) {
                Ok(s) => s,
                Err(e) => {
                    engine_fault(
                        client, engine, opts, metrics, hb_lease, lease,
                        &e, &mut report,
                    );
                    consecutive_faults += 1;
                    if consecutive_faults >= MAX_CONSECUTIVE_ENGINE_FAULTS
                    {
                        return Err(e);
                    }
                    continue 'outer;
                }
            };
            consecutive_faults = 0;
            let done = step.done;
            let rows: Vec<ChunkRow> = step
                .seqs
                .into_iter()
                .enumerate()
                .filter(|(_, s)| !s.tokens.is_empty() || s.finished)
                .map(|(i, s)| ChunkRow {
                    index: batch.indices[i],
                    tokens: s.tokens,
                    logps: s.logps,
                    finished: s.finished,
                })
                .collect();
            let finished =
                rows.iter().filter(|r| r.finished).count() as u64;
            let tokens: u64 =
                rows.iter().map(|r| r.tokens.len() as u64).sum();
            let sent = if rows.is_empty() {
                client.renew_lease(lease, opts.ttl_ms)
            } else {
                client.put_chunk(lease, gen_version, rows)
            };
            if let Err(e) = sent {
                // Only a lost lease is recoverable: the coordinator
                // requeued our rows to a peer, so abandon the batch —
                // regeneration elsewhere is the exactly-once path.
                // Anything else (transport death, a protocol violation
                // like an externally squatted cell) must fail loudly,
                // not silently retry-loop.
                if !format!("{e:#}").contains("lease") {
                    return Err(e);
                }
                report.leases_lost += 1;
                if let Some(m) = metrics {
                    m.inc("leases_lost", 1);
                }
                crate::log_warn!(
                    &opts.name,
                    "lease {lease} lost mid-generation; abandoning the \
                     batch (rows requeued to a peer)"
                );
                hb_lease.store(0, Ordering::SeqCst);
                let _ = engine.finish_generate();
                continue 'outer;
            }
            report.chunks += 1;
            report.samples += finished;
            report.tokens += tokens;
            if let Some(m) = metrics {
                if finished > 0 {
                    m.inc("rollout_samples", finished);
                }
                if tokens > 0 {
                    m.inc("rollout_tokens", tokens);
                }
            }
            // ...and at every chunk boundary (never mid-chunk: engines
            // keep in-flight sequences on their begin-time weights).
            swap_weights(client, engine, &mut mirror, metrics, &mut report)?;
            if done {
                break;
            }
            if abort() {
                // Killed mid-generation: leave the lease to expire; the
                // coordinator will requeue whatever we did not finish.
                break 'outer;
            }
        }
        hb_lease.store(0, Ordering::SeqCst);
        let _ = engine.finish_generate();
        // An anchored timeline already mirrors this span into the
        // telemetry log (with the ambient trace); record directly only
        // when no timeline will do it for us.
        if !timeline.is_some_and(|t| t.bridges_telemetry()) {
            crate::telemetry::record_span(
                "generate",
                &opts.name,
                reply.trace,
                gen_span_t0,
                crate::telemetry::now_us(),
            );
        }
        if let (Some(tl), Some(start)) = (timeline, t0) {
            tl.record(&opts.name, "generate", start, tl.now());
        }
    }
    // An abort mid-generation leaves buffered decode state; clear it so
    // the engine is reusable if the caller restarts the loop.
    if engine.gen_state().is_some() {
        let _ = engine.finish_generate();
    }
    Ok(report)
}
