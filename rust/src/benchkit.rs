//! Bench harness (criterion is unavailable offline): warmup + timed
//! iterations with mean/p50/p99 reporting, plus table rendering for the
//! paper-reproduction benches.

use std::time::Instant;

use crate::util::stats::Summary;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub std_s: f64,
}

impl BenchResult {
    pub fn per_second(&self) -> f64 {
        1.0 / self.mean_s.max(1e-12)
    }
}

/// Time `f` for `iters` iterations after `warmup` unrecorded runs.
pub fn bench(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: impl FnMut(),
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        s.add(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters: s.len(),
        mean_s: s.mean(),
        p50_s: s.p50(),
        p99_s: s.p99(),
        std_s: s.std(),
    }
}

/// Pretty duration.
pub fn fmt_dur(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

/// Render bench results as an aligned table.
pub fn render_results(results: &[BenchResult]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<42} {:>8} {:>10} {:>10} {:>10}\n",
        "benchmark", "iters", "mean", "p50", "p99"
    ));
    for r in results {
        out.push_str(&format!(
            "{:<42} {:>8} {:>10} {:>10} {:>10}\n",
            r.name,
            r.iters,
            fmt_dur(r.mean_s),
            fmt_dur(r.p50_s),
            fmt_dur(r.p99_s),
        ));
    }
    out
}

/// Simple aligned table builder for paper-table reproduction output.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.header.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::new();
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!("{c:>w$}  "));
            }
            line.trim_end().to_string() + "\n"
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push_str(&format!(
            "{}\n",
            "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len())
        ));
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut n = 0u64;
        let r = bench("noop", 2, 10, || n += 1);
        assert_eq!(n, 12, "warmup + iters executed");
        assert_eq!(r.iters, 10);
        assert!(r.mean_s >= 0.0 && r.p99_s >= r.p50_s);
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_dur(5e-9).ends_with("ns"));
        assert!(fmt_dur(5e-6).ends_with("µs"));
        assert!(fmt_dur(5e-3).ends_with("ms"));
        assert!(fmt_dur(5.0).ends_with('s'));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["config", "throughput"]);
        t.row(&["a".into(), "1.0".into()]);
        t.row(&["long-config-name".into(), "2.0".into()]);
        let s = t.render();
        assert!(s.contains("long-config-name"));
        assert!(s.lines().count() >= 4);
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
