//! Weight distribution plane — delta-aware, binary, fanned out.
//!
//! The paper's §4.2.2 deferred parameter update moves new policy weights
//! from the Trainer to every rollout instance once per iteration; at
//! scale that transfer is the single largest control-plane payload in
//! the system. This module gives it a dedicated plane instead of riding
//! the JSONL snapshot verb:
//!
//! * **Delta manifests.** [`crate::runtime::ParamSet`] tracks a *content
//!   version* per tensor (`ParamSet::rebase_onto`, applied centrally by
//!   `ParamStore::try_publish`). A publish therefore knows exactly which
//!   tensors changed, and [`WeightsMeta`] describes the whole model in a
//!   few bytes per tensor — subscribers long-poll the tiny manifest and
//!   pull only stale tensors.
//! * **Binary transport.** Tensor payloads travel over the storage-unit
//!   frame codec (`transfer_queue::frame`): length-prefixed, bit-exact
//!   f32s, bounded decode. JSON never touches a tensor on this path.
//! * **Fan-out.** The coordinator pushes changed tensors to every
//!   attached storage unit at publish time; workers fetch from the units
//!   and fall back through the coordinator (`fetch_tensors` verb) for
//!   misses — the same availability-over-purity failover the sample
//!   data plane uses.
//!
//! [`WeightMirror`] is the worker-side engine (poll → diff → fetch →
//! assemble); [`WeightPlane`] is the coordinator-side ledger
//! (subscriber lag, bytes shipped full vs delta).

pub mod mirror;
pub mod plane;

pub use mirror::WeightMirror;
pub use plane::WeightPlane;

use std::sync::Arc;

use crate::runtime::{DType, HostTensor, ParamSet};

/// Wire metadata for one tensor of the published manifest: everything a
/// subscriber needs to decide staleness and budget the fetch, at a few
/// dozen bytes per tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorMeta {
    /// Position in the manifest (== position in `ParamSet::tensors`).
    pub index: u32,
    /// Version of the publish that last changed this tensor's bytes.
    pub content_version: u64,
    pub dtype: DType,
    pub shape: Vec<usize>,
    /// Payload size (chunking budget; never trusted for allocation).
    pub bytes: u64,
}

/// The delta manifest a `subscribe_weights_meta` long-poll returns:
/// snapshot version, per-tensor content versions, and the storage-unit
/// endpoints serving the binary payloads (`None` = slot has no attached
/// unit; fetch via the coordinator).
#[derive(Debug, Clone, PartialEq)]
pub struct WeightsMeta {
    pub version: u64,
    pub tensors: Vec<TensorMeta>,
    pub endpoints: Vec<Option<String>>,
}

impl WeightsMeta {
    /// Describe `params` as a wire manifest.
    pub fn describe(
        params: &ParamSet,
        endpoints: Vec<Option<String>>,
    ) -> Self {
        WeightsMeta {
            version: params.version,
            tensors: params
                .tensors
                .iter()
                .enumerate()
                .map(|(i, t)| TensorMeta {
                    index: i as u32,
                    content_version: params.content_version(i),
                    dtype: t.dtype,
                    shape: t.shape.clone(),
                    bytes: t.size_bytes() as u64,
                })
                .collect(),
            endpoints,
        }
    }

    /// Indices a mirror holding `have` must refetch to reach this
    /// manifest. A tensor-count mismatch (re-architected model) makes
    /// everything stale.
    pub fn stale_indices(&self, have: &ParamSet) -> Vec<u32> {
        let full = have.tensors.len() != self.tensors.len();
        self.tensors
            .iter()
            .filter(|m| {
                full || m.content_version
                    != have.content_version(m.index as usize)
            })
            .map(|m| m.index)
            .collect()
    }

    /// Total payload bytes behind `indices` (fetch budgeting).
    pub fn bytes_for(&self, indices: &[u32]) -> u64 {
        indices
            .iter()
            .filter_map(|&i| self.tensors.get(i as usize))
            .map(|m| m.bytes)
            .sum()
    }
}

/// The tensors of `params` that changed in its own publish (content
/// version == snapshot version) — the delta the coordinator fans out to
/// units. Arc clones only; payloads are shared.
pub fn delta_updates(
    params: &ParamSet,
) -> Vec<(u32, u64, Arc<HostTensor>)> {
    params
        .tensors
        .iter()
        .enumerate()
        .filter(|(i, _)| params.content_version(*i) == params.version)
        .map(|(i, t)| (i as u32, params.content_version(i), t.clone()))
        .collect()
}

/// Every tensor of `params` (the at-attach seeding push: a fresh unit
/// has no history, so it gets the whole snapshot).
pub fn full_updates(
    params: &ParamSet,
) -> Vec<(u32, u64, Arc<HostTensor>)> {
    params
        .tensors
        .iter()
        .enumerate()
        .map(|(i, t)| (i as u32, params.content_version(i), t.clone()))
        .collect()
}

/// One subscriber's progress through the published snapshots (the
/// version it reported holding on its latest meta poll).
#[derive(Debug, Clone, PartialEq)]
pub struct SubscriberLag {
    pub id: String,
    /// Snapshot version the subscriber last reported holding.
    pub version: u64,
}

/// Weight-plane slice of the `stats` verb: published state, per-path
/// byte ledgers, and subscriber lag.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct WeightPlaneStats {
    /// Latest published snapshot version.
    pub published_version: u64,
    /// Tensors in the published manifest.
    pub tensors: usize,
    /// Tensor-payload bytes shipped as full JSONL snapshots
    /// (`subscribe_weights`, the legacy path).
    pub full_payload_bytes: u64,
    /// Tensor-payload bytes shipped through the coordinator's binary
    /// fallback (`fetch_tensors` verb).
    pub delta_payload_bytes: u64,
    /// Tensor-payload bytes pushed to attached storage units at
    /// publish/attach time (the fan-out legs).
    pub unit_push_bytes: u64,
    /// Known subscribers and the snapshot version each last reported.
    pub subscribers: Vec<SubscriberLag>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(xs: &[f32]) -> HostTensor {
        HostTensor::from_f32(vec![xs.len()], xs).unwrap()
    }

    #[test]
    fn manifest_diff_finds_exactly_the_changed_tensors() {
        let v1 = ParamSet::new(1, vec![t(&[1.0]), t(&[2.0]), t(&[3.0])]);
        let v2 = ParamSet::new(2, vec![t(&[1.0]), t(&[9.0]), t(&[3.0])])
            .rebase_onto(&v1);
        let meta = WeightsMeta::describe(&v2, vec![None]);
        assert_eq!(meta.version, 2);
        assert_eq!(meta.stale_indices(&v1), vec![1]);
        assert_eq!(meta.stale_indices(&v2), Vec::<u32>::new());
        // Tensor-count change ⇒ everything is stale.
        let reshaped = ParamSet::new(0, vec![t(&[0.0])]);
        assert_eq!(meta.stale_indices(&reshaped), vec![0, 1, 2]);
        assert_eq!(meta.bytes_for(&[1]), 4);
    }

    #[test]
    fn delta_updates_carry_only_this_publishes_tensors() {
        let v1 = ParamSet::new(1, vec![t(&[1.0]), t(&[2.0])]);
        let v2 = ParamSet::new(2, vec![t(&[1.0]), t(&[5.0])])
            .rebase_onto(&v1);
        let delta = delta_updates(&v2);
        assert_eq!(delta.len(), 1);
        assert_eq!(delta[0].0, 1);
        assert_eq!(delta[0].1, 2);
        assert_eq!(full_updates(&v2).len(), 2);
        // An untouched republish has an empty delta: metadata only.
        let v3 = ParamSet::new(3, vec![t(&[1.0]), t(&[5.0])])
            .rebase_onto(&v2);
        assert!(delta_updates(&v3).is_empty());
    }
}
