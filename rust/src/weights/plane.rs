//! Coordinator-side weight-plane ledger: who is subscribed, how far
//! behind each subscriber is, and how many tensor-payload bytes each
//! distribution path has shipped. Pure bookkeeping — the dispatch code
//! in `service::Session` feeds it; the `stats` verb and
//! `asyncflow info` read it.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::{SubscriberLag, WeightPlaneStats};

/// Shared ledger for the weight distribution plane. Cheap to update on
/// the hot path: counters are atomics, the subscriber map is touched
/// once per (long-poll) meta request.
#[derive(Default)]
pub struct WeightPlane {
    /// subscriber id → snapshot version it last reported holding.
    subscribers: Mutex<BTreeMap<String, u64>>,
    full_payload_bytes: AtomicU64,
    delta_payload_bytes: AtomicU64,
    unit_push_bytes: AtomicU64,
}

impl WeightPlane {
    pub fn new() -> Self {
        WeightPlane::default()
    }

    /// Record that `id` polled the manifest while holding `version`.
    pub fn note_subscriber(&self, id: &str, version: u64) {
        self.subscribers
            .lock()
            .unwrap()
            .insert(id.to_string(), version);
    }

    /// Account tensor bytes shipped as a full JSONL snapshot.
    pub fn add_full_bytes(&self, n: u64) {
        self.full_payload_bytes.fetch_add(n, Ordering::Relaxed);
    }

    /// Account tensor bytes shipped through the coordinator's
    /// `fetch_tensors` fallback.
    pub fn add_delta_bytes(&self, n: u64) {
        self.delta_payload_bytes.fetch_add(n, Ordering::Relaxed);
    }

    /// Account tensor bytes pushed to attached storage units.
    pub fn add_unit_push_bytes(&self, n: u64) {
        self.unit_push_bytes.fetch_add(n, Ordering::Relaxed);
    }

    /// Snapshot the ledger for the `stats` verb.
    pub fn stats(
        &self,
        published_version: u64,
        tensors: usize,
    ) -> WeightPlaneStats {
        WeightPlaneStats {
            published_version,
            tensors,
            full_payload_bytes: self.full_payload_bytes.load(Ordering::Relaxed),
            delta_payload_bytes: self
                .delta_payload_bytes
                .load(Ordering::Relaxed),
            unit_push_bytes: self.unit_push_bytes.load(Ordering::Relaxed),
            subscribers: self
                .subscribers
                .lock()
                .unwrap()
                .iter()
                .map(|(id, v)| SubscriberLag {
                    id: id.clone(),
                    version: *v,
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_tracks_subscribers_and_bytes() {
        let plane = WeightPlane::new();
        plane.note_subscriber("w0", 0);
        plane.note_subscriber("w1", 2);
        plane.note_subscriber("w0", 3);
        plane.add_full_bytes(100);
        plane.add_delta_bytes(25);
        plane.add_unit_push_bytes(50);
        plane.add_delta_bytes(5);
        let s = plane.stats(3, 4);
        assert_eq!(s.published_version, 3);
        assert_eq!(s.tensors, 4);
        assert_eq!(s.full_payload_bytes, 100);
        assert_eq!(s.delta_payload_bytes, 30);
        assert_eq!(s.unit_push_bytes, 50);
        assert_eq!(
            s.subscribers,
            vec![
                SubscriberLag { id: "w0".into(), version: 3 },
                SubscriberLag { id: "w1".into(), version: 2 },
            ]
        );
    }
}
