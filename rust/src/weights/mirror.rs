//! Worker-side weight mirror: the poll → diff → fetch → assemble engine
//! behind delta-aware parameter sync.
//!
//! A [`WeightMirror`] holds the worker's current [`ParamSet`] and, on
//! [`WeightMirror::sync`], long-polls the coordinator for a newer
//! manifest ([`super::WeightsMeta`] — a few bytes per tensor), computes
//! which tensors are stale by content version, and pulls only those:
//! binary frames from the storage-unit endpoints the manifest names,
//! with a via-coordinator `fetch_tensors` fallback for misses and dead
//! units. Unchanged tensors are shared by `Arc` from the previous
//! snapshot — an unchanged-tensor republish costs metadata only.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::runtime::{HostTensor, ParamSet};
use crate::service::ServiceClient;
use crate::transfer_queue::{RemoteUnit, UnitHandle};

use super::{TensorMeta, WeightsMeta};

/// Per-request payload budget when fetching from units: stale tensors
/// are grouped so one round-trip carries at most this many bytes
/// (groups rotate across endpoints, spreading a big delta over the
/// whole fan-out tier).
const FETCH_CHUNK_BYTES: u64 = 8 * 1024 * 1024;

/// How many manifest re-reads a single sync tolerates before giving up:
/// each retry means a publish landed mid-fetch (content versions moved
/// under us), which converges fast or not at all.
const MAX_VERSION_RACES: usize = 4;

/// A worker's local replica of the published weights.
pub struct WeightMirror {
    id: String,
    current: ParamSet,
    /// Lazily dialed binary connections, by endpoint. A transport
    /// failure drops the connection; the tensors fall back through the
    /// coordinator and the endpoint is re-dialed on its next turn.
    conns: HashMap<String, Arc<RemoteUnit>>,
}

impl WeightMirror {
    /// An empty mirror (version 0, no tensors) identified as `id` in
    /// the coordinator's subscriber ledger.
    pub fn new(id: impl Into<String>) -> Self {
        WeightMirror {
            id: id.into(),
            current: ParamSet::new(0, vec![]),
            conns: HashMap::new(),
        }
    }

    /// Treat an empty mirror as already holding snapshot `version`
    /// (with no tensors). For engines constructed with weights at a
    /// known version: the first sync then fires only on something
    /// *newer* — like the legacy `subscribe_weights` path — at the
    /// cost of a full fetch when it does (the tensor-count mismatch
    /// marks everything stale). No-op once the mirror holds tensors.
    pub fn assume_version(&mut self, version: u64) {
        if self.current.tensors.is_empty()
            && version > self.current.version
        {
            self.current = ParamSet::new(version, vec![]);
        }
    }

    /// Snapshot version currently held.
    pub fn version(&self) -> u64 {
        self.current.version
    }

    /// The currently held snapshot.
    pub fn current(&self) -> &ParamSet {
        &self.current
    }

    /// Poll for weights newer than what the mirror holds, long-polling
    /// up to `timeout_ms` (0 = pure poll). Returns the fresh snapshot
    /// when one was installed, `None` when nothing newer exists.
    pub fn sync(
        &mut self,
        client: &ServiceClient,
        timeout_ms: u64,
    ) -> Result<Option<ParamSet>> {
        let Some(mut meta) = client.subscribe_weights_meta(
            &self.id,
            self.current.version,
            timeout_ms,
        )?
        else {
            return Ok(None);
        };
        for _race in 0..MAX_VERSION_RACES {
            validate(&meta)?;
            if let Some(fresh) = self.try_assemble(client, &meta)? {
                self.current = fresh.clone();
                return Ok(Some(fresh));
            }
            // A publish landed mid-fetch and some content version we
            // wanted no longer exists anywhere — re-read the manifest
            // (pure poll: it is strictly newer than what we hold).
            match client.subscribe_weights_meta(
                &self.id,
                self.current.version,
                0,
            )? {
                Some(m) => meta = m,
                None => return Ok(None),
            }
        }
        bail!(
            "weight sync did not converge after {MAX_VERSION_RACES} \
             manifest races (publishes are outpacing the fetch)"
        );
    }

    /// One assembly attempt against a fixed manifest. `None` means a
    /// wanted tensor was missing from both its unit and the coordinator
    /// — a version race; the caller re-reads the manifest.
    fn try_assemble(
        &mut self,
        client: &ServiceClient,
        meta: &WeightsMeta,
    ) -> Result<Option<ParamSet>> {
        let n = meta.tensors.len();
        let same_shape = self.current.tensors.len() == n;
        let mut slots: Vec<Option<Arc<HostTensor>>> = vec![None; n];
        let mut stale: Vec<&TensorMeta> = Vec::new();
        for (i, m) in meta.tensors.iter().enumerate() {
            if same_shape
                && m.content_version == self.current.content_version(i)
            {
                slots[i] = Some(self.current.tensors[i].clone());
            } else {
                stale.push(m);
            }
        }

        // Binary fetch from the fan-out tier, chunked by byte budget.
        let endpoints: Vec<&String> =
            meta.endpoints.iter().flatten().collect();
        let mut missing: Vec<u32> = Vec::new();
        for (k, wants) in chunk_wants(&stale).into_iter().enumerate() {
            let mut served = false;
            if let Some(ep) = endpoints
                .get(k % endpoints.len().max(1))
                .map(|e| e.as_str())
            {
                let conn = self
                    .conns
                    .entry(ep.to_string())
                    .or_insert_with(|| Arc::new(RemoteUnit::new(ep)))
                    .clone();
                match conn.fetch_tensors(&wants) {
                    Ok(items) => {
                        served = true;
                        for ((idx, _cv), item) in wants.iter().zip(items)
                        {
                            match item {
                                Some(t) => {
                                    slots[*idx as usize] = Some(t)
                                }
                                None => missing.push(*idx),
                            }
                        }
                    }
                    Err(_) => {
                        // Dead unit: drop the connection, relay this
                        // chunk through the coordinator.
                        self.conns.remove(ep);
                    }
                }
            }
            if !served {
                missing.extend(wants.iter().map(|(i, _)| *i));
            }
        }

        // Coordinator fallback. Content versions identify bytes, so an
        // entry is usable iff its version matches the manifest — even
        // when the server has already published past `meta.version`.
        if !missing.is_empty() {
            for (idx, cv, t) in
                client.fetch_tensors(meta.version, &missing)?
            {
                let i = idx as usize;
                if i < n && meta.tensors[i].content_version == cv {
                    slots[i] = Some(t);
                }
            }
        }

        let Some(tensors) =
            slots.into_iter().collect::<Option<Vec<_>>>()
        else {
            return Ok(None);
        };
        let cvs: Vec<u64> =
            meta.tensors.iter().map(|m| m.content_version).collect();
        Ok(Some(ParamSet::with_content_versions(
            meta.version,
            tensors,
            cvs,
        )))
    }
}

/// Group stale tensors into ≤ [`FETCH_CHUNK_BYTES`] requests (a tensor
/// bigger than the budget gets a chunk of its own).
fn chunk_wants(stale: &[&TensorMeta]) -> Vec<Vec<(u32, u64)>> {
    let mut groups: Vec<Vec<(u32, u64)>> = Vec::new();
    let mut cur: Vec<(u32, u64)> = Vec::new();
    let mut cur_bytes = 0u64;
    for m in stale {
        if !cur.is_empty()
            && cur_bytes.saturating_add(m.bytes) > FETCH_CHUNK_BYTES
        {
            groups.push(std::mem::take(&mut cur));
            cur_bytes = 0;
        }
        cur.push((m.index, m.content_version));
        cur_bytes = cur_bytes.saturating_add(m.bytes);
    }
    if !cur.is_empty() {
        groups.push(cur);
    }
    groups
}

/// Reject manifests whose indices do not match their positions — the
/// mirror addresses slots by position, so a scrambled manifest would
/// install tensors at the wrong offsets.
fn validate(meta: &WeightsMeta) -> Result<()> {
    for (i, m) in meta.tensors.iter().enumerate() {
        if m.index as usize != i {
            bail!(
                "malformed weights manifest: tensor {} labeled index {}",
                i,
                m.index
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::DType;

    fn meta(bytes: &[u64]) -> Vec<TensorMeta> {
        bytes
            .iter()
            .enumerate()
            .map(|(i, &b)| TensorMeta {
                index: i as u32,
                content_version: 1,
                dtype: DType::F32,
                shape: vec![b as usize / 4],
                bytes: b,
            })
            .collect()
    }

    #[test]
    fn chunking_respects_the_byte_budget() {
        let metas = meta(&[
            FETCH_CHUNK_BYTES - 8,
            16,
            FETCH_CHUNK_BYTES + 1, // oversized: its own chunk
            4,
            4,
        ]);
        let refs: Vec<&TensorMeta> = metas.iter().collect();
        let groups = chunk_wants(&refs);
        assert_eq!(
            groups,
            vec![
                vec![(0, 1)],
                vec![(1, 1)],
                vec![(2, 1)],
                vec![(3, 1), (4, 1)],
            ]
        );
    }

    #[test]
    fn scrambled_manifest_is_rejected() {
        let mut m = WeightsMeta {
            version: 1,
            tensors: meta(&[4, 4]),
            endpoints: vec![],
        };
        assert!(validate(&m).is_ok());
        m.tensors[1].index = 5;
        assert!(validate(&m).is_err());
    }
}
