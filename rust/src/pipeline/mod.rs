//! Stage-graph pipeline layer (paper §5: "modular and customizable
//! user experience" made literal): RL dataflows declared as data, not
//! hand-wired worker closures.
//!
//! * A [`Stage`] is one processing step: declared input (task +
//!   columns + micro-batch geometry) and a `process(batch) ->
//!   Vec<PutRow>` body. Built-in stages live in [`stages`]; user
//!   algorithms implement the trait.
//! * A [`PipelineSpec`] is the declarative graph: the TransferQueue
//!   tasks it consumes plus one [`StageNode`] per worker. Swapping the
//!   algorithm (GRPO → best-of-n rejection sampling) is a different
//!   spec, not different plumbing — see `Trainer::run` and
//!   `examples/custom_pipeline.rs`.
//! * The [`PipelineRunner`] compiles a spec into supervised
//!   producer–consumer loops that speak only [`ServiceClient`] verbs
//!   (`get_batch` → `process` → `put_batch`; the rollout node rides the
//!   elastic lease verbs). A failing or panicking stage trips the
//!   shared shutdown flag and closes the queue so every peer drains —
//!   error hoisting lives in `exec::WorkerPool::spawn_supervised`, not
//!   in each algorithm.
//!
//! Because stages touch nothing but a `ServiceClient`, any stage also
//! runs out-of-process: `asyncflow stage --connect HOST:PORT --stage
//! <name>` attaches a reward model or filter to a live run over TCP
//! ([`run_remote_stage`]), registering its input task mid-run if the
//! session does not have it yet (resident rows replay). Remote stages
//! consume under **consumer leases** (`get_batch` → `process` →
//! `put_batch` → `ack_batch`), so killing one mid-batch requeues its
//! in-flight rows instead of stranding them — see
//! [`run_service_stage`].

pub mod stages;

pub use stages::{
    build_train_batch, FilterTopK, GroupAdvantage, PromptFeeder,
    ReferenceLogp, RuleReward, TrainPlan, TrainPublish,
};

use std::collections::HashSet;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::coordinator::Timeline;
use crate::exec::{Shutdown, WorkerPool};
use crate::metrics::Registry;
use crate::rollout::{run_worker, WorkerOptions};
use crate::runtime::{PolicyEngine, Sampler};
use crate::service::{
    ConsumerSpec, GetBatchSpec, PutRow, ServiceClient, TaskDecl,
};
use crate::transfer_queue::{Batch, Column};

/// Long-poll interval for stage pulls: long enough to park the thread,
/// short enough that shutdown is observed promptly.
const PULL_TIMEOUT_MS: u64 = 50;

/// Default consumer-lease TTL for remote stages (`asyncflow stage`
/// overrides it with `--lease-ttl-ms`). Size it above the stage's
/// worst-case per-batch latency: there is no mid-batch heartbeat, so a
/// live stage that outruns its TTL has its rows requeued to a peer and
/// its own late work discarded at ack time — survivable (the loop
/// continues, identical replays are absorbed, conservation holds) but
/// wasted effort. Erring long costs only crash-detection latency,
/// since a killed stage's rows requeue immediately on disconnect
/// anyway; the TTL is the backstop for wedged-but-open sockets.
pub const DEFAULT_STAGE_LEASE_TTL_MS: u64 = 10_000;

/// Execution context handed to every [`Stage::process`] call: the
/// service client (the only data path), shared metrics/timeline sinks,
/// and the cooperative shutdown flag (stages that block internally —
/// e.g. on a staleness gate — must watch it).
pub struct StageCtx<'a> {
    /// This node's name: timeline row, metrics key, log prefix.
    pub worker: &'a str,
    pub client: &'a ServiceClient,
    pub metrics: &'a Registry,
    pub timeline: &'a Timeline,
    pub shutdown: &'a Shutdown,
}

/// Declared input of a consuming stage: which task's controller feeds
/// it, the columns it reads, and its micro-batch geometry.
#[derive(Debug, Clone)]
pub struct StageInput {
    /// Task whose controller feeds this stage.
    pub task: String,
    /// Columns fetched for each served row.
    pub columns: Vec<Column>,
    /// Max rows per pull.
    pub count: usize,
    /// Min rows before a pull completes (drain mode serves fewer).
    pub min: usize,
    /// The task's readiness contract — what [`StageInput::task_decl`]
    /// registers. Defaults to `columns`; widened via
    /// [`StageInput::gate_on`] when a row must not be served until
    /// columns the stage does not fetch exist.
    pub requires: Vec<Column>,
    /// Consumer-lease TTL applied when this stage runs over a remote
    /// transport (defaults to [`DEFAULT_STAGE_LEASE_TTL_MS`]; `0` opts
    /// out of leases entirely). In-process stages never lease — they
    /// share the coordinator's fate, so the fast path is safe.
    pub lease_ttl_ms: u64,
}

impl StageInput {
    /// An input contract fetching `columns` from `task` with default
    /// geometry (8 rows per pull, streaming min 1).
    pub fn new(task: impl Into<String>, columns: Vec<Column>) -> Self {
        let requires = columns.clone();
        StageInput {
            task: task.into(),
            columns,
            count: 8,
            min: 1,
            requires,
            lease_ttl_ms: DEFAULT_STAGE_LEASE_TTL_MS,
        }
    }

    /// Set the micro-batch geometry (`count` rows per pull, at least
    /// `min` before the pull completes).
    pub fn with_batch(mut self, count: usize, min: usize) -> Self {
        self.count = count;
        self.min = min;
        self
    }

    /// Override the remote consumer-lease TTL (`0` disables leases —
    /// the pre-lease consume-is-final behavior).
    pub fn with_lease_ttl(mut self, ttl_ms: u64) -> Self {
        self.lease_ttl_ms = ttl_ms;
        self
    }

    /// Widen the readiness contract beyond the fetched columns: rows
    /// are served only once every `requires` column exists, including
    /// ones this stage never reads (e.g. the best-of-n filter gates on
    /// `RefLogp` so every stage that could still want a rejected row's
    /// payload has run before the filter evicts it).
    pub fn gate_on(mut self, requires: Vec<Column>) -> Self {
        self.requires = requires;
        self
    }

    /// The wire-form task declaration for this input (registration of
    /// brand-new tasks attaching mid-run).
    pub fn task_decl(&self) -> TaskDecl {
        TaskDecl::new(self.task.clone(), self.requires.clone())
    }
}

/// One processing stage of a pipeline graph.
///
/// Consuming stages receive the batches their declared input yields and
/// return rows to write back (`put_batch`); the columns those rows
/// carry are what unlock downstream stages — the graph's edges are
/// column readiness, never direct stage-to-stage channels. Source
/// stages (no input) are called with an empty batch until they report
/// [`Stage::finished`]; they must block (e.g. on a gate) or finish
/// rather than spin.
///
/// Deliberately NOT `Send`: stages may own thread-confined engines
/// (PJRT clients), so specs carry `Send` *factories* and each stage is
/// built inside its worker thread.
pub trait Stage {
    /// Process one input batch; returned rows are written back through
    /// `put_batch`.
    fn process(
        &mut self,
        ctx: &StageCtx<'_>,
        batch: &Batch,
    ) -> Result<Vec<PutRow>>;

    /// True once this stage has produced/consumed everything it ever
    /// will. A finished *driver* node ends the whole run.
    fn finished(&self) -> bool {
        false
    }
}

/// Stages are built *inside* their worker thread — engines hold
/// non-`Send` PJRT state — so specs carry factories, not stages.
pub type StageFactory =
    Box<dyn FnOnce() -> Result<Box<dyn Stage>> + Send>;
/// Factory for a rollout node's policy engine (same thread-confinement
/// rule).
pub type EngineFactory =
    Box<dyn FnOnce() -> Result<Box<dyn PolicyEngine>> + Send>;

/// An elastic lease-based rollout worker node: drives a
/// [`PolicyEngine`] through the incremental decode API over the lease
/// verbs (`lease_prompts`, `put_chunk`, ...) — the same loop `asyncflow
/// rollout-worker --connect` runs, so extra workers can join the graph
/// over TCP mid-run.
pub struct RolloutNode {
    pub build: EngineFactory,
    pub temperature: f32,
    pub top_k: usize,
    pub seed: u64,
    pub opts: WorkerOptions,
}

/// What a node executes.
pub enum StageKind {
    /// `get_batch` → `process` → `put_batch` loop; a source when
    /// `input` is `None` (`process` runs with an empty batch until the
    /// stage finishes).
    Service {
        input: Option<StageInput>,
        build: StageFactory,
    },
    /// Elastic lease-based rollout worker.
    Rollout(RolloutNode),
}

/// One worker node of a [`PipelineSpec`].
pub struct StageNode {
    pub name: String,
    pub kind: StageKind,
    /// A driver's completion ends the whole run: the runner trips
    /// shutdown and closes the queue so every other stage drains.
    pub driver: bool,
}

impl StageNode {
    /// A consuming (or, with `input: None`, producing) stage node.
    pub fn stage(
        name: impl Into<String>,
        input: Option<StageInput>,
        build: StageFactory,
    ) -> Self {
        StageNode {
            name: name.into(),
            kind: StageKind::Service { input, build },
            driver: false,
        }
    }

    /// A source node: no input task; `process` is called with an empty
    /// batch until the stage finishes.
    pub fn source(name: impl Into<String>, build: StageFactory) -> Self {
        Self::stage(name, None, build)
    }

    /// A driver node: like [`StageNode::stage`], but its completion
    /// tears the whole graph down (the train/update stage of an RL
    /// graph).
    pub fn driver(
        name: impl Into<String>,
        input: StageInput,
        build: StageFactory,
    ) -> Self {
        let mut node = Self::stage(name, Some(input), build);
        node.driver = true;
        node
    }

    /// An elastic rollout worker node.
    pub fn rollout(name: impl Into<String>, node: RolloutNode) -> Self {
        StageNode {
            name: name.into(),
            kind: StageKind::Rollout(node),
            driver: false,
        }
    }
}

/// Declarative description of an RL dataflow: the tasks (TransferQueue
/// controllers) the graph consumes plus the worker nodes that animate
/// them. Compiled by [`PipelineRunner::run`].
#[derive(Default)]
pub struct PipelineSpec {
    /// Tasks the graph needs. Missing ones are registered on the
    /// session at run start (existing tasks are reused as-is).
    pub tasks: Vec<TaskDecl>,
    pub nodes: Vec<StageNode>,
}

impl PipelineSpec {
    /// An empty spec (no tasks, no nodes).
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a task the graph consumes (registered at run start if missing).
    pub fn task(mut self, decl: TaskDecl) -> Self {
        self.tasks.push(decl);
        self
    }

    /// Add a worker node.
    pub fn node(mut self, node: StageNode) -> Self {
        self.nodes.push(node);
        self
    }
}

/// What a pipeline run produced: the shared metrics registry and
/// timeline every stage recorded into, plus the wall time.
pub struct PipelineReport {
    pub metrics: Arc<Registry>,
    pub timeline: Arc<Timeline>,
    pub wall_time_s: f64,
}

/// Compiles a [`PipelineSpec`] into supervised producer–consumer
/// worker loops over a [`ServiceClient`]. The session behind the
/// client must already be initialized; the runner registers any task
/// the spec names that the session lacks.
pub struct PipelineRunner {
    client: ServiceClient,
    metrics: Arc<Registry>,
    timeline: Arc<Timeline>,
    shutdown: Shutdown,
}

impl PipelineRunner {
    /// A runner over `client` with fresh metrics/timeline/shutdown state.
    pub fn new(client: ServiceClient) -> Self {
        PipelineRunner {
            client,
            metrics: Arc::new(Registry::new()),
            timeline: Arc::new(Timeline::anchored()),
            shutdown: Shutdown::new(),
        }
    }

    /// The shared shutdown flag — external watchdogs can trip it to
    /// abort a run.
    pub fn shutdown_handle(&self) -> Shutdown {
        self.shutdown.clone()
    }

    /// Register every task the spec names that the session lacks.
    fn ensure_tasks(&self, tasks: &[TaskDecl]) -> Result<()> {
        if tasks.is_empty() {
            return Ok(());
        }
        let existing: HashSet<String> = self
            .client
            .stats()?
            .tasks
            .into_iter()
            .map(|t| t.name)
            .collect();
        for decl in tasks {
            if !existing.contains(&decl.name) {
                ensure_task(&self.client, decl.clone())?;
            }
        }
        Ok(())
    }

    /// Run the graph to completion: returns when a driver node
    /// finishes (it closes the queue and every stage drains), or with
    /// the first worker error after the supervised drain.
    pub fn run(self, spec: PipelineSpec) -> Result<PipelineReport> {
        self.ensure_tasks(&spec.tasks)?;
        let mut pool = WorkerPool::new();
        for node in spec.nodes {
            self.spawn_node(&mut pool, node);
        }
        pool.join()?;
        let wall = self.timeline.now();
        Ok(PipelineReport {
            metrics: self.metrics,
            timeline: self.timeline,
            wall_time_s: wall,
        })
    }

    fn spawn_node(&self, pool: &mut WorkerPool, node: StageNode) {
        let name = node.name.clone();
        let client = self.client.clone();
        let metrics = self.metrics.clone();
        let timeline = self.timeline.clone();
        let shutdown = self.shutdown.clone();
        // On worker failure the supervised wrapper trips shutdown and
        // then drains the data fabric through the same service verb a
        // remote stage would use — transport-agnostic teardown.
        let drain_client = self.client.clone();
        let drain = move || {
            let _ = drain_client.shutdown();
        };
        let driver = node.driver;
        match node.kind {
            StageKind::Service { input, build } => {
                pool.spawn_supervised(
                    name.clone(),
                    shutdown.clone(),
                    drain,
                    move || {
                        let mut stage = build()?;
                        let ctx = StageCtx {
                            worker: &name,
                            client: &client,
                            metrics: &*metrics,
                            timeline: &*timeline,
                            shutdown: &shutdown,
                        };
                        run_service_stage(
                            &ctx,
                            input.as_ref(),
                            stage.as_mut(),
                        )?;
                        if driver {
                            // The driver finishing IS the end of the
                            // run: close the stream so peers drain.
                            shutdown.trigger();
                            let _ = client.shutdown();
                        }
                        Ok(())
                    },
                );
            }
            StageKind::Rollout(r) => {
                let RolloutNode { build, temperature, top_k, seed, opts } =
                    r;
                pool.spawn_supervised(
                    name,
                    shutdown.clone(),
                    drain,
                    move || {
                        let mut engine = build()?;
                        let mut sampler =
                            Sampler::new(temperature, top_k, seed);
                        run_worker(
                            &client,
                            engine.as_mut(),
                            &mut sampler,
                            &opts,
                            Some(&*metrics),
                            Some(&*timeline),
                            &|| shutdown.is_triggered(),
                        )?;
                        Ok(())
                    },
                );
            }
        }
    }
}

/// Drive one stage loop against a service client: `get_batch` →
/// `process` → `put_batch` → `ack` (pure production for sources).
/// Returns when the stream closes, the stage finishes, or
/// `ctx.shutdown` trips. Shared by the in-process [`PipelineRunner`]
/// and the out-of-process `asyncflow stage` attach path — the loops are
/// byte-identical, only the transport differs.
///
/// Crash safety: when the client is remote ([`ServiceClient::is_remote`])
/// and the input's `lease_ttl_ms` is nonzero, every pull runs under a
/// consumer lease that is acked only *after* the stage's outputs were
/// written back. Killing the stage process at any point — mid-`process`,
/// mid-`put_batch`, before the ack — requeues its in-flight rows to the
/// surviving consumers (immediately on disconnect, at TTL expiry as the
/// backstop), and a replayed identical `put_batch` is absorbed
/// server-side, so rows are processed effectively once. In-process
/// stages keep the lease-free fast path: they cannot outlive the
/// coordinator.
pub fn run_service_stage(
    ctx: &StageCtx<'_>,
    input: Option<&StageInput>,
    stage: &mut dyn Stage,
) -> Result<()> {
    match input {
        None => {
            let empty = Batch {
                indices: vec![],
                columns: vec![],
                rows: vec![],
            };
            while !ctx.shutdown.is_triggered() && !stage.finished() {
                let rows = stage.process(ctx, &empty)?;
                if !rows.is_empty() {
                    ctx.client.put_batch(rows)?;
                }
            }
        }
        Some(input) => {
            let consumer = (ctx.client.is_remote()
                && input.lease_ttl_ms > 0)
                .then(|| ConsumerSpec {
                    id: ctx.worker.to_string(),
                    ttl_ms: input.lease_ttl_ms,
                });
            let spec = GetBatchSpec {
                task: input.task.clone(),
                group: 0,
                columns: input.columns.clone(),
                count: input.count,
                min: input.min,
                timeout_ms: PULL_TIMEOUT_MS,
                consumer,
            };
            while !ctx.shutdown.is_triggered() && !stage.finished() {
                let Some(leased) = ctx
                    .client
                    .get_batch_leased_blocking_until(&spec, || {
                        ctx.shutdown.is_triggered()
                    })?
                else {
                    break;
                };
                // One span per micro-batch on this stage's track (a
                // batch mixes rows from many traces, so it is untraced).
                let span_t0 = crate::telemetry::now_us();
                let rows = stage.process(ctx, &leased.batch)?;
                if !rows.is_empty() {
                    ctx.client.put_batch(rows)?;
                }
                crate::telemetry::record_span(
                    "process",
                    ctx.worker,
                    0,
                    span_t0,
                    crate::telemetry::now_us(),
                );
                // Outputs are durable — only now is consumption final.
                // An EXPIRED lease is survivable, not fatal: the server
                // already requeued the rows (this stage outran its
                // TTL), a peer will reprocess them, and our identical
                // outputs were absorbed — so conservation holds and the
                // loop keeps serving. Anything else (transport death,
                // protocol error) still aborts the stage.
                if let Err(e) = leased.ack() {
                    if !format!("{e:#}").contains("unknown or expired") {
                        return Err(e);
                    }
                    ctx.metrics.inc("lease_overrun_batches", 1);
                }
            }
        }
    }
    Ok(())
}

/// Register a task, tolerating the attach race: two workers probing
/// `stats` concurrently may both see the task absent and both try to
/// register it — losing that race means a peer created the task we
/// wanted, which is success, not failure.
fn ensure_task(client: &ServiceClient, decl: TaskDecl) -> Result<()> {
    let name = decl.name.clone();
    match client.register_task(decl) {
        Ok(()) => Ok(()),
        Err(e) => {
            let known_now =
                client.stats()?.tasks.iter().any(|t| t.name == name);
            if known_now {
                Ok(())
            } else {
                Err(e)
            }
        }
    }
}

/// Run one stage attached to a live session over any transport — the
/// body of `asyncflow stage --connect`. The stage's input task is
/// registered if the session does not have it yet (a brand-new stage
/// attaching mid-run replays resident rows). On a stage error the
/// whole graph is drained (shutdown verb) before the error propagates,
/// so a failing out-of-process stage can never silently stall its
/// peers — and because remote pulls run under consumer leases (see
/// [`run_service_stage`]), even a `kill -9` mid-batch just requeues the
/// stage's in-flight rows to its surviving peers. Returns the stage's
/// metrics registry (anything the stage recorded — e.g. the reward
/// series — lives in THIS process, not the coordinator's; callers
/// should surface it).
pub fn run_remote_stage(
    client: &ServiceClient,
    name: &str,
    input: Option<&StageInput>,
    stage: &mut dyn Stage,
    shutdown: &Shutdown,
) -> Result<Registry> {
    if let Some(input) = input {
        let known = client
            .stats()?
            .tasks
            .iter()
            .any(|t| t.name == input.task);
        if !known {
            ensure_task(client, input.task_decl())?;
        }
    }
    let metrics = Registry::new();
    let timeline = Timeline::anchored();
    let ctx = StageCtx {
        worker: name,
        client,
        metrics: &metrics,
        timeline: &timeline,
        shutdown,
    };
    let result = run_service_stage(&ctx, input, stage);
    // Hand this stage's span log to the coordinator for the merged
    // `asyncflow trace` timeline (best-effort, error path included —
    // the spans up to the failure are often the interesting ones).
    client.push_telemetry(name);
    match result {
        Ok(()) => Ok(metrics),
        Err(e) => {
            crate::log_warn!(
                name,
                "stage failed; draining the graph: {e:#}"
            );
            let _ = client.shutdown();
            Err(e)
        }
    }
}

/// Construct a built-in stage by name — the registry behind `asyncflow
/// stage --stage <name>`. Returns the stage's default input contract
/// (callers may override `task`/geometry) and the stage itself.
///
/// Scale-out caveat: only the *stateless* `reward` stage may compete
/// with other consumers on the same task (rows are consumed exactly
/// once, so extra graders just add throughput). `advantage` and
/// `filter` hold per-instance group state — two instances on one task
/// would split prompt groups between their assemblers and neither
/// group half would ever complete, stalling the graph. Attach those
/// only as the sole consumer of their input task.
pub fn builtin_stage(
    name: &str,
    group_size: usize,
    survivors: usize,
) -> Result<(StageInput, Box<dyn Stage>)> {
    Ok(match name {
        "reward" => (
            RuleReward::input(),
            Box::new(RuleReward::new()) as Box<dyn Stage>,
        ),
        "advantage" => (
            GroupAdvantage::input(),
            Box::new(GroupAdvantage::new(group_size)) as Box<dyn Stage>,
        ),
        "filter" => (
            FilterTopK::input(),
            Box::new(FilterTopK::new(group_size, survivors)?)
                as Box<dyn Stage>,
        ),
        other => bail!(
            "unknown stage {other:?} (reward|advantage|filter)"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ParamSet;
    use crate::service::{Session, SessionSpec};
    use crate::transfer_queue::{TaskSpec, Value};

    fn xcol() -> Column {
        Column::Custom("x".into())
    }

    fn ycol() -> Column {
        Column::Custom("y".into())
    }

    /// Source: emits `total` single-cell rows, one per call.
    struct NumberSource {
        next: i32,
        total: i32,
    }

    impl Stage for NumberSource {
        fn process(
            &mut self,
            _ctx: &StageCtx<'_>,
            _batch: &Batch,
        ) -> Result<Vec<PutRow>> {
            if self.next >= self.total {
                return Ok(vec![]);
            }
            let v = self.next;
            self.next += 1;
            Ok(vec![PutRow::new(vec![(
                xcol(),
                Value::I32s(vec![v]),
            )])])
        }

        fn finished(&self) -> bool {
            self.next >= self.total
        }
    }

    /// Map: y = 2x.
    struct Doubler;

    impl Stage for Doubler {
        fn process(
            &mut self,
            _ctx: &StageCtx<'_>,
            batch: &Batch,
        ) -> Result<Vec<PutRow>> {
            let mut out = Vec::with_capacity(batch.len());
            for (idx, row) in batch.indices.iter().zip(&batch.rows) {
                let x = row[0].as_i32s().unwrap()[0];
                out.push(PutRow::at(*idx, vec![(
                    ycol(),
                    Value::I32s(vec![2 * x]),
                )]));
            }
            Ok(out)
        }
    }

    /// Driver: collects `want` doubled rows, verifying y = 2x.
    struct Collector {
        want: usize,
        got: std::collections::HashSet<u64>,
    }

    impl Stage for Collector {
        fn process(
            &mut self,
            ctx: &StageCtx<'_>,
            batch: &Batch,
        ) -> Result<Vec<PutRow>> {
            for (idx, row) in batch.indices.iter().zip(&batch.rows) {
                let x = row[0].as_i32s().unwrap()[0];
                let y = row[1].as_i32s().unwrap()[0];
                anyhow::ensure!(y == 2 * x, "bad edge: {x} -> {y}");
                anyhow::ensure!(
                    self.got.insert(idx.0),
                    "row {idx} served twice"
                );
                ctx.metrics.inc("collected", 1);
            }
            Ok(vec![])
        }

        fn finished(&self) -> bool {
            self.got.len() >= self.want
        }
    }

    fn session_with(tasks: Vec<TaskSpec>) -> Arc<Session> {
        Arc::new(
            Session::init_engines(
                SessionSpec { storage_units: 1, tasks },
                ParamSet::new(0, vec![]),
            )
            .unwrap(),
        )
    }

    #[test]
    fn runner_compiles_graph_and_driver_completion_ends_the_run() {
        // "double" exists at init; "collect" is declared by the spec
        // and registered by the runner.
        let session = session_with(vec![TaskSpec::new(
            "double",
            vec![xcol()],
        )]);
        let runner =
            PipelineRunner::new(ServiceClient::in_proc(session.clone()));
        let total = 20;
        let spec = PipelineSpec::new()
            .task(TaskDecl::new("collect", vec![ycol()]))
            .node(StageNode::source(
                "numbers",
                Box::new(move || {
                    Ok(Box::new(NumberSource { next: 0, total })
                        as Box<dyn Stage>)
                }),
            ))
            .node(StageNode::stage(
                "double",
                Some(
                    StageInput::new("double", vec![xcol()])
                        .with_batch(4, 1),
                ),
                Box::new(|| Ok(Box::new(Doubler) as Box<dyn Stage>)),
            ))
            .node(StageNode::driver(
                "collect",
                StageInput::new("collect", vec![xcol(), ycol()])
                    .with_batch(4, 1),
                Box::new(move || {
                    Ok(Box::new(Collector {
                        want: total as usize,
                        got: Default::default(),
                    }) as Box<dyn Stage>)
                }),
            ));
        let report = runner.run(spec).unwrap();
        assert_eq!(report.metrics.counter("collected"), total as u64);
        assert!(
            session.stats().unwrap().closed,
            "driver completion closed the stream"
        );
        // All three nodes left timeline/metrics state behind? (Only the
        // collector records metrics; the run itself must have ended.)
        assert!(report.wall_time_s >= 0.0);
    }

    #[test]
    fn stage_error_drains_the_graph_in_proc() {
        struct Exploder;
        impl Stage for Exploder {
            fn process(
                &mut self,
                _ctx: &StageCtx<'_>,
                _batch: &Batch,
            ) -> Result<Vec<PutRow>> {
                anyhow::bail!("stage exploded")
            }
        }
        let session = session_with(vec![TaskSpec::new(
            "double",
            vec![xcol()],
        )]);
        let runner =
            PipelineRunner::new(ServiceClient::in_proc(session.clone()));
        let spec = PipelineSpec::new()
            .task(TaskDecl::new("collect", vec![ycol()]))
            .node(StageNode::source(
                "numbers",
                Box::new(|| {
                    Ok(Box::new(NumberSource { next: 0, total: 8 })
                        as Box<dyn Stage>)
                }),
            ))
            .node(StageNode::stage(
                "exploder",
                Some(
                    StageInput::new("double", vec![xcol()])
                        .with_batch(4, 1),
                ),
                Box::new(|| Ok(Box::new(Exploder) as Box<dyn Stage>)),
            ))
            .node(StageNode::driver(
                "collect",
                StageInput::new("collect", vec![xcol(), ycol()])
                    .with_batch(4, 1),
                Box::new(|| {
                    Ok(Box::new(Collector {
                        want: 8,
                        got: Default::default(),
                    }) as Box<dyn Stage>)
                }),
            ));
        let err = runner.run(spec).unwrap_err();
        assert!(
            format!("{err:#}").contains("stage exploded"),
            "got {err:#}"
        );
        assert!(
            session.stats().unwrap().closed,
            "failed stage must drain the whole graph"
        );
    }

    #[test]
    fn builtin_stage_registry_resolves_names() {
        assert!(builtin_stage("reward", 4, 2).is_ok());
        assert!(builtin_stage("advantage", 4, 2).is_ok());
        assert!(builtin_stage("filter", 4, 2).is_ok());
        assert!(builtin_stage("filter", 4, 0).is_err(), "bad survivors");
        assert!(builtin_stage("nope", 4, 2).is_err());
    }
}
