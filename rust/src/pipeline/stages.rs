//! Built-in pipeline stages: the GRPO workflow's six boxes (prompt
//! feeder, lease rollout — see [`super::RolloutNode`] — reference
//! logp, rule reward, group advantage, train+publish) plus the
//! best-of-n rejection-sampling filter. Each is an ordinary [`Stage`]
//! impl: algorithms compose them into a [`super::PipelineSpec`] instead
//! of hand-writing worker loops, and any of them can attach to a live
//! run out-of-process through `asyncflow stage`.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::coordinator::{GroupAssembler, IterationGate};
use crate::data::{self, MathTaskGen, PAD};
use crate::runtime::{PolicyEngine, TrainBatch, TrainEngine};
use crate::service::PutRow;
use crate::transfer_queue::{Batch, Column, GlobalIndex, Value};

use super::{Stage, StageCtx, StageInput};

fn col(name: &str) -> Column {
    Column::Custom(name.to_string())
}

// ===========================================================================
// Prompt feeder (source)
// ===========================================================================

/// Source stage: ingests G-replicated prompts one *group* per call —
/// each `process` emits a single prompt group's G rows, so rollout
/// workers start leasing while the rest of the iteration is still
/// being fed (streaming ingest, one `put_batch` round-trip per group).
/// Gated on iteration staleness (§4.2.1): the feeder blocks at each
/// iteration boundary so rollout never runs more than `staleness`
/// iterations ahead.
pub struct PromptFeeder {
    gen: MathTaskGen,
    gate: Arc<IterationGate>,
    group_size: usize,
    prompts_per_iter: usize,
    iterations: u64,
    next_iter: u64,
    next_group: usize,
}

impl PromptFeeder {
    /// A feeder emitting `iterations x (global_batch/group_size)` groups.
    pub fn new(
        gen: MathTaskGen,
        gate: Arc<IterationGate>,
        iterations: usize,
        global_batch: usize,
        group_size: usize,
    ) -> Self {
        let prompts_per_iter = global_batch / group_size;
        PromptFeeder {
            gen,
            gate,
            group_size,
            prompts_per_iter,
            // A degenerate geometry (group larger than the global
            // batch) has nothing to feed: finish immediately instead
            // of looping.
            iterations: if prompts_per_iter == 0 {
                0
            } else {
                iterations as u64
            },
            next_iter: 0,
            next_group: 0,
        }
    }
}

impl Stage for PromptFeeder {
    fn process(
        &mut self,
        ctx: &StageCtx<'_>,
        _batch: &Batch,
    ) -> Result<Vec<PutRow>> {
        let iter = self.next_iter;
        if iter >= self.iterations {
            return Ok(vec![]);
        }
        if self.next_group == 0
            && !self.gate.wait_to_produce(iter, ctx.shutdown)
        {
            // Aborted while gated: nothing more to produce.
            self.next_iter = self.iterations;
            return Ok(vec![]);
        }
        let t0 = ctx.timeline.now();
        let task = self.gen.next_task();
        let group =
            iter * self.prompts_per_iter as u64 + self.next_group as u64;
        let rows = (0..self.group_size)
            .map(|_| {
                PutRow::new(vec![
                    (
                        Column::Prompts,
                        Value::I32s(task.prompt_tokens.clone()),
                    ),
                    (col("answer"), Value::Text(task.answer.to_string())),
                    (col("group"), Value::U64(group)),
                    (col("iter"), Value::U64(iter)),
                ])
            })
            .collect();
        self.next_group += 1;
        if self.next_group == self.prompts_per_iter {
            self.next_group = 0;
            self.next_iter += 1;
        }
        ctx.timeline.record(ctx.worker, "ingest", t0, ctx.timeline.now());
        Ok(rows)
    }

    fn finished(&self) -> bool {
        self.next_iter >= self.iterations
    }
}

// ===========================================================================
// Reference scorer
// ===========================================================================

/// Frozen-reference logp scorer: rebuilds the fixed-geometry sequence
/// from (Prompts, Responses), scores it, and emits the
/// response-aligned `RefLogp` slice.
pub struct ReferenceLogp {
    engine: Box<dyn PolicyEngine>,
    prompt_len: usize,
    max_len: usize,
}

impl ReferenceLogp {
    /// A scorer over `engine` with the given sequence geometry.
    pub fn new(
        engine: Box<dyn PolicyEngine>,
        prompt_len: usize,
        max_len: usize,
    ) -> Self {
        ReferenceLogp { engine, prompt_len, max_len }
    }

    /// Standard input contract (full engine batches).
    pub fn input(batch: usize) -> StageInput {
        StageInput::new(
            "reference",
            vec![Column::Prompts, Column::Responses],
        )
        .with_batch(batch, batch)
    }
}

impl Stage for ReferenceLogp {
    fn process(
        &mut self,
        ctx: &StageCtx<'_>,
        batch: &Batch,
    ) -> Result<Vec<PutRow>> {
        let mut ids = Vec::with_capacity(batch.len());
        let mut resp_lens = Vec::with_capacity(batch.len());
        for row in &batch.rows {
            let prompt = row[0].as_i32s().context("prompts column")?;
            let resp = row[1].as_i32s().context("responses column")?;
            let mut full = prompt.to_vec();
            full.extend_from_slice(resp);
            full.resize(self.max_len, PAD);
            resp_lens.push(resp.len());
            ids.push(full);
        }
        let t0 = ctx.timeline.now();
        let ref_logp = self.engine.logprobs(&ids)?;
        ctx.timeline.record(
            ctx.worker,
            "ref_logp",
            t0,
            ctx.timeline.now(),
        );
        let p = self.prompt_len;
        let mut rows = Vec::with_capacity(batch.len());
        for ((idx, lp), rl) in
            batch.indices.iter().zip(&ref_logp).zip(&resp_lens)
        {
            rows.push(PutRow::at(*idx, vec![(
                Column::RefLogp,
                Value::F32s(lp[p - 1..p - 1 + rl].to_vec()),
            )]));
        }
        Ok(rows)
    }
}

// ===========================================================================
// Rule reward
// ===========================================================================

/// Rule-based reward grader: checks each response against the ground
/// truth carried in the `answer` metadata column. Stateless — the
/// canonical stage to scale out over TCP (`asyncflow stage --stage
/// reward`): extra graders compete on the same task, each row graded
/// exactly once.
#[derive(Default)]
pub struct RuleReward;

impl RuleReward {
    /// A stateless rule grader.
    pub fn new() -> Self {
        RuleReward
    }

    /// Standard input contract (streaming: min 1).
    pub fn input() -> StageInput {
        StageInput::new(
            "reward",
            vec![Column::Responses, col("answer")],
        )
    }
}

impl Stage for RuleReward {
    fn process(
        &mut self,
        ctx: &StageCtx<'_>,
        batch: &Batch,
    ) -> Result<Vec<PutRow>> {
        let t0 = ctx.timeline.now();
        let mut rows = Vec::with_capacity(batch.len());
        for (idx, row) in batch.indices.iter().zip(&batch.rows) {
            let resp = row[0].as_i32s().context("responses column")?;
            let answer: i64 = row[1]
                .as_text()
                .context("answer column")?
                .parse()
                .context("bad answer metadata")?;
            let reward = data::grade_response(resp, answer);
            ctx.metrics.record_now("reward", reward as f64);
            ctx.metrics.record_now("response_len", resp.len() as f64);
            rows.push(PutRow::at(*idx, vec![(
                Column::Rewards,
                Value::F32(reward),
            )]));
        }
        ctx.timeline.record(ctx.worker, "grade", t0, ctx.timeline.now());
        Ok(rows)
    }
}

// ===========================================================================
// Group advantage (GRPO)
// ===========================================================================

/// GRPO group assembly + normalization: buffers reward scalars until a
/// prompt group of size G completes, then emits the whole group's
/// normalized `Advantages` (metadata-scale state only — never
/// payloads).
pub struct GroupAdvantage {
    assembler: GroupAssembler,
}

impl GroupAdvantage {
    /// An assembler for prompt groups of size `group_size`.
    pub fn new(group_size: usize) -> Self {
        GroupAdvantage { assembler: GroupAssembler::new(group_size) }
    }

    /// Standard input contract (streaming: min 1).
    pub fn input() -> StageInput {
        StageInput::new("advantage", vec![Column::Rewards, col("group")])
    }
}

impl Stage for GroupAdvantage {
    fn process(
        &mut self,
        ctx: &StageCtx<'_>,
        batch: &Batch,
    ) -> Result<Vec<PutRow>> {
        let t0 = ctx.timeline.now();
        let mut rows = Vec::new();
        for (idx, row) in batch.indices.iter().zip(&batch.rows) {
            let reward = row[0].as_f32().context("rewards column")?;
            let group = row[1].as_u64().context("group column")?;
            if let Some(done) = self.assembler.add(group, *idx, reward) {
                for (midx, adv) in done {
                    rows.push(PutRow::at(midx, vec![(
                        Column::Advantages,
                        Value::F32(adv),
                    )]));
                }
            }
        }
        // Only completed groups make an "advantage" phase on the
        // timeline (and, through an anchored timeline's telemetry
        // bridge, a span on this stage's Fig. 11 track) — buffering a
        // partial group is not normalization work.
        if !rows.is_empty() {
            ctx.timeline.record(
                ctx.worker,
                "advantage",
                t0,
                ctx.timeline.now(),
            );
        }
        Ok(rows)
    }
}

// ===========================================================================
// Best-of-n filter (rejection sampling)
// ===========================================================================

/// Best-of-n rejection sampling: collect each prompt group's G graded
/// rollouts, keep the top-k by reward, and emit `Advantages = 1.0` for
/// the survivors only. Losers never gain the `Advantages` column, so
/// they never become train-ready — selection is expressed purely
/// through column readiness, with zero bespoke plumbing between
/// stages.
///
/// Rejected rollouts are evicted (GC) as their group completes —
/// without this, every non-survivor's full payload would stay
/// resident for the whole run. The default [`FilterTopK::input`]
/// therefore gates readiness on `RefLogp` too: by the time a group is
/// filterable, every stage that could still want a loser's payload
/// has already run, so eviction cannot race a fetch. Graphs with no
/// reference stage must override the gate AND set `evict_rejects =
/// false`.
///
/// Holds per-instance group state: run exactly ONE filter per task
/// (see the scale-out caveat on [`super::builtin_stage`]).
pub struct FilterTopK {
    group_size: usize,
    survivors: usize,
    /// GC rejected rollouts when their group completes (default true).
    pub evict_rejects: bool,
    pending: HashMap<u64, Vec<(GlobalIndex, f32)>>,
}

impl FilterTopK {
    /// A filter keeping the top `survivors` of each `group_size` group.
    pub fn new(group_size: usize, survivors: usize) -> Result<Self> {
        if group_size == 0 || survivors == 0 || survivors > group_size {
            bail!(
                "need 1 <= survivors <= group_size, got {survivors} of \
                 {group_size}"
            );
        }
        Ok(FilterTopK {
            group_size,
            survivors,
            evict_rejects: true,
            pending: HashMap::new(),
        })
    }

    /// Standard input contract (streaming: min 1): fetches the reward
    /// + group metadata, gated on `RefLogp` so loser eviction is safe
    /// (see the type-level docs).
    pub fn input() -> StageInput {
        StageInput::new("filter", vec![Column::Rewards, col("group")])
            .gate_on(vec![
                Column::Rewards,
                Column::RefLogp,
                col("group"),
            ])
    }
}

impl Stage for FilterTopK {
    fn process(
        &mut self,
        ctx: &StageCtx<'_>,
        batch: &Batch,
    ) -> Result<Vec<PutRow>> {
        let t0 = ctx.timeline.now();
        let mut rows = Vec::new();
        let mut rejects: Vec<GlobalIndex> = Vec::new();
        for (idx, row) in batch.indices.iter().zip(&batch.rows) {
            let reward = row[0].as_f32().context("rewards column")?;
            let group = row[1].as_u64().context("group column")?;
            let entry = self.pending.entry(group).or_default();
            entry.push((*idx, reward));
            if entry.len() < self.group_size {
                continue;
            }
            let mut members = self.pending.remove(&group).unwrap();
            // Highest reward first; ties resolve to the oldest row so
            // selection is deterministic.
            members.sort_by(|a, b| {
                b.1.partial_cmp(&a.1)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.0 .0.cmp(&b.0 .0))
            });
            ctx.metrics.inc("filter_groups", 1);
            for (rank, (midx, _)) in members.into_iter().enumerate() {
                if rank < self.survivors {
                    ctx.metrics.inc("filter_survivors", 1);
                    rows.push(PutRow::at(midx, vec![(
                        Column::Advantages,
                        Value::F32(1.0),
                    )]));
                } else {
                    rejects.push(midx);
                }
            }
        }
        if self.evict_rejects && !rejects.is_empty() {
            ctx.client.evict(&rejects)?;
            ctx.metrics.inc("filter_evicted", rejects.len() as u64);
        }
        // Same rule as GroupAdvantage: selection work (a group was
        // ranked) earns a "filter" span; pure buffering does not.
        if !rows.is_empty() || !rejects.is_empty() {
            ctx.timeline.record(
                ctx.worker,
                "filter",
                t0,
                ctx.timeline.now(),
            );
        }
        Ok(rows)
    }
}

// ===========================================================================
// Train + publish (driver)
// ===========================================================================

/// Geometry + schedule for [`TrainPublish`].
#[derive(Debug, Clone)]
pub struct TrainPlan {
    /// Actor updates to run before the stage finishes (and, as the
    /// graph's driver, ends the run).
    pub iterations: u64,
    /// Train steps per iteration (trained samples per iteration /
    /// engine batch).
    pub steps_per_iter: u64,
    /// Engine micro-batch B.
    pub batch: usize,
    pub prompt_len: usize,
    pub max_len: usize,
    pub lr: f32,
}

/// The train-and-publish driver: pulls full train batches, runs
/// `train_step`, evicts consumed rows (global-batch GC), and at every
/// iteration boundary publishes weights (`weight_sync_notify`) *before*
/// releasing the staleness gate — so newly admitted prompts can only
/// roll out on weights at least as new as the iteration that admitted
/// them. Its completion ends the run (spawn it as a driver node).
pub struct TrainPublish {
    engine: Box<dyn TrainEngine>,
    gate: Arc<IterationGate>,
    plan: TrainPlan,
    iters_done: u64,
    steps_in_iter: u64,
}

impl TrainPublish {
    /// A driver over `engine` gated by `gate`, following `plan`.
    pub fn new(
        engine: Box<dyn TrainEngine>,
        gate: Arc<IterationGate>,
        plan: TrainPlan,
    ) -> Self {
        TrainPublish {
            engine,
            gate,
            plan,
            iters_done: 0,
            steps_in_iter: 0,
        }
    }

    /// Standard input contract (full engine batches).
    pub fn input(batch: usize) -> StageInput {
        StageInput::new(
            "train",
            vec![
                Column::Prompts,
                Column::Responses,
                Column::OldLogp,
                Column::RefLogp,
                Column::Advantages,
            ],
        )
        .with_batch(batch, batch)
    }
}

impl Stage for TrainPublish {
    fn process(
        &mut self,
        ctx: &StageCtx<'_>,
        batch: &Batch,
    ) -> Result<Vec<PutRow>> {
        let tb = build_train_batch(
            batch,
            self.plan.batch,
            self.plan.max_len,
            self.plan.prompt_len,
            self.plan.lr,
        )?;
        let t0 = ctx.timeline.now();
        let tm = self.engine.train_step(&tb)?;
        ctx.timeline.record(
            ctx.worker,
            "train_step",
            t0,
            ctx.timeline.now(),
        );
        ctx.metrics.inc("samples_trained", batch.len() as u64);
        let tokens: u64 = tb
            .mask
            .iter()
            .map(|row| row.iter().sum::<f32>() as u64)
            .sum();
        ctx.metrics.inc("tokens_trained", tokens);
        ctx.metrics.record_now("loss", tm.loss as f64);
        ctx.metrics.record_now("kl", tm.kl as f64);
        ctx.metrics.record_now("nll", tm.nll as f64);
        ctx.metrics.record_now("grad_norm", tm.grad_norm as f64);
        // Evict consumed rows (global-batch GC).
        ctx.client.evict(&batch.indices)?;

        self.steps_in_iter += 1;
        if self.steps_in_iter == self.plan.steps_per_iter {
            self.steps_in_iter = 0;
            self.iters_done += 1;
            // Publish weights BEFORE releasing the gate (on-policy in
            // sync mode; bounded staleness otherwise).
            let t0 = ctx.timeline.now();
            ctx.client.weight_sync_notify(self.engine.export_params())?;
            ctx.timeline.record(
                ctx.worker,
                "weight_sync",
                t0,
                ctx.timeline.now(),
            );
            self.gate.complete_iteration();
            ctx.metrics.inc("iterations_done", 1);
            ctx.metrics.record_now("iteration", self.iters_done as f64);
        }
        Ok(vec![])
    }

    fn finished(&self) -> bool {
        self.iters_done >= self.plan.iterations
    }
}

// ===========================================================================
// Train-batch assembly
// ===========================================================================

/// Assemble the fixed-geometry [`TrainBatch`] from variable-length TQ
/// rows (restoring geometry from lengths — the receive side of the
/// paper's no-padding transfer, §3.5).
pub fn build_train_batch(
    batch: &Batch,
    b: usize,
    t_len: usize,
    p_len: usize,
    lr: f32,
) -> Result<TrainBatch> {
    let mut ids = Vec::with_capacity(b);
    let mut advantages = Vec::with_capacity(b);
    let mut old_logp = Vec::with_capacity(b);
    let mut ref_logp = Vec::with_capacity(b);
    let mut mask = Vec::with_capacity(b);
    for row in &batch.rows {
        let prompt = row[0].as_i32s().context("prompts column")?;
        let resp = row[1].as_i32s().context("responses column")?;
        let old = row[2].as_f32s().context("old_logp column")?;
        let rlp = row[3].as_f32s().context("ref_logp column")?;
        let adv = row[4].as_f32().context("advantages column")?;
        let rl = resp.len();
        anyhow::ensure!(old.len() == rl && rlp.len() == rl,
            "logp slice length mismatch: resp={rl} old={} ref={}",
            old.len(), rlp.len());

        let mut full = prompt.to_vec();
        full.extend_from_slice(resp);
        full.resize(t_len, PAD);
        ids.push(full);
        advantages.push(adv);

        let mut o = vec![0.0f32; t_len - 1];
        let mut rf = vec![0.0f32; t_len - 1];
        let mut m = vec![0.0f32; t_len - 1];
        o[p_len - 1..p_len - 1 + rl].copy_from_slice(old);
        rf[p_len - 1..p_len - 1 + rl].copy_from_slice(rlp);
        for v in m.iter_mut().skip(p_len - 1).take(rl) {
            *v = 1.0;
        }
        old_logp.push(o);
        ref_logp.push(rf);
        mask.push(m);
    }
    Ok(TrainBatch { ids, advantages, old_logp, ref_logp, mask, lr })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Timeline;
    use crate::exec::Shutdown;
    use crate::metrics::Registry;
    use crate::runtime::ParamSet;
    use crate::service::{ServiceClient, Session, SessionSpec};

    fn test_ctx_session() -> (Arc<Session>, ServiceClient) {
        let session = Arc::new(
            Session::init_engines(
                SessionSpec::grpo(),
                ParamSet::new(0, vec![]),
            )
            .unwrap(),
        );
        let client = ServiceClient::in_proc(session.clone());
        (session, client)
    }

    fn batch_of(rows: Vec<(u64, Vec<Value>)>, columns: Vec<Column>) -> Batch {
        Batch {
            indices: rows.iter().map(|(i, _)| GlobalIndex(*i)).collect(),
            rows: rows.into_iter().map(|(_, r)| r).collect(),
            columns,
        }
    }

    /// Drive a stage's process() directly with a synthetic context.
    fn run_process(
        stage: &mut dyn Stage,
        batch: &Batch,
    ) -> Result<Vec<PutRow>> {
        let (_session, client) = test_ctx_session();
        let metrics = Registry::new();
        let timeline = Timeline::new();
        let shutdown = Shutdown::new();
        let ctx = StageCtx {
            worker: "test",
            client: &client,
            metrics: &metrics,
            timeline: &timeline,
            shutdown: &shutdown,
        };
        stage.process(&ctx, batch)
    }

    #[test]
    fn rule_reward_grades_against_answer_metadata() {
        let mut stage = RuleReward::new();
        // "7\n" == answer 7 -> full reward; "9\n" parses but misses
        // the ground truth -> partial shaping reward only.
        let good = data::render_answer(7);
        let bad = data::render_answer(9);
        let batch = batch_of(
            vec![
                (0, vec![Value::I32s(good), Value::Text("7".into())]),
                (1, vec![Value::I32s(bad), Value::Text("7".into())]),
            ],
            vec![Column::Responses, col("answer")],
        );
        let rows = run_process(&mut stage, &batch).unwrap();
        assert_eq!(rows.len(), 2);
        let reward_of = |r: &PutRow| match r.cells[0].1 {
            Value::F32(v) => v,
            ref other => panic!("expected a reward, got {other:?}"),
        };
        assert!((reward_of(&rows[0]) - 1.0).abs() < 1e-5);
        let partial = reward_of(&rows[1]);
        assert!(
            partial < 0.9 && partial > 0.0,
            "wrong answer earns shaping reward only: {partial}"
        );
    }

    #[test]
    fn rule_reward_rejects_malformed_answer() {
        let mut stage = RuleReward::new();
        let batch = batch_of(
            vec![(
                0,
                vec![
                    Value::I32s(vec![49]),
                    Value::Text("not-a-number".into()),
                ],
            )],
            vec![Column::Responses, col("answer")],
        );
        assert!(run_process(&mut stage, &batch).is_err());
    }

    #[test]
    fn group_advantage_releases_complete_groups() {
        let mut stage = GroupAdvantage::new(2);
        let batch = batch_of(
            vec![
                (0, vec![Value::F32(1.0), Value::U64(5)]),
                (1, vec![Value::F32(0.0), Value::U64(6)]),
            ],
            vec![Column::Rewards, col("group")],
        );
        assert!(run_process(&mut stage, &batch).unwrap().is_empty());
        let batch2 = batch_of(
            vec![
                (2, vec![Value::F32(0.0), Value::U64(5)]),
                (3, vec![Value::F32(1.0), Value::U64(6)]),
            ],
            vec![Column::Rewards, col("group")],
        );
        let rows = run_process(&mut stage, &batch2).unwrap();
        assert_eq!(rows.len(), 4, "both groups complete");
    }

    #[test]
    fn filter_keeps_top_k_by_reward() {
        let mut stage = FilterTopK::new(4, 2).unwrap();
        let batch = batch_of(
            vec![
                (0, vec![Value::F32(0.1), Value::U64(0)]),
                (1, vec![Value::F32(0.9), Value::U64(0)]),
                (2, vec![Value::F32(0.5), Value::U64(0)]),
                (3, vec![Value::F32(0.9), Value::U64(0)]),
            ],
            vec![Column::Rewards, col("group")],
        );
        let rows = run_process(&mut stage, &batch).unwrap();
        let survivors: Vec<u64> = rows
            .iter()
            .map(|r| r.index.unwrap().0)
            .collect();
        // Top-2 by reward; the 0.9 tie resolves to the older row (1).
        assert_eq!(survivors, vec![1, 3]);
        for r in &rows {
            assert_eq!(r.cells[0].1, Value::F32(1.0));
        }
    }

    #[test]
    fn filter_streams_partial_groups() {
        let mut stage = FilterTopK::new(3, 1).unwrap();
        let b1 = batch_of(
            vec![
                (0, vec![Value::F32(0.2), Value::U64(0)]),
                (1, vec![Value::F32(0.8), Value::U64(0)]),
            ],
            vec![Column::Rewards, col("group")],
        );
        assert!(run_process(&mut stage, &b1).unwrap().is_empty());
        let b2 = batch_of(
            vec![(2, vec![Value::F32(0.5), Value::U64(0)])],
            vec![Column::Rewards, col("group")],
        );
        let rows = run_process(&mut stage, &b2).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].index.unwrap().0, 1, "argmax reward");
    }

    #[test]
    fn feeder_streams_one_group_per_call_within_budget() {
        let gen = MathTaskGen::new(0, 16);
        let gate = IterationGate::new(1);
        let mut stage = PromptFeeder::new(gen, gate, 2, 8, 4);
        assert!(!stage.finished());
        let empty = Batch { indices: vec![], columns: vec![], rows: vec![] };
        let (_s, client) = test_ctx_session();
        let metrics = Registry::new();
        let timeline = Timeline::new();
        let shutdown = Shutdown::new();
        let ctx = StageCtx {
            worker: "feeder",
            client: &client,
            metrics: &metrics,
            timeline: &timeline,
            shutdown: &shutdown,
        };
        // 2 iterations x 2 groups of 4: one group per call so rollout
        // can start on group 0 while group 1 is still being fed.
        let mut groups_seen = Vec::new();
        for call in 0..4 {
            let rows = stage.process(&ctx, &empty).unwrap();
            assert_eq!(rows.len(), 4, "call {call} emits one group");
            let group = rows[0]
                .cells
                .iter()
                .find(|(c, _)| *c == col("group"))
                .and_then(|(_, v)| v.as_u64())
                .unwrap();
            assert!(
                rows.iter().all(|r| {
                    r.cells.iter().any(|(c, v)| {
                        *c == col("group") && v.as_u64() == Some(group)
                    })
                }),
                "all rows of a call share one group id"
            );
            groups_seen.push(group);
        }
        assert_eq!(groups_seen, vec![0, 1, 2, 3], "distinct group ids");
        assert!(stage.finished(), "budget of 2 iterations exhausted");
        assert!(stage.process(&ctx, &empty).unwrap().is_empty());
        // Degenerate geometry: nothing to feed, finished immediately.
        let degenerate = PromptFeeder::new(
            MathTaskGen::new(0, 16),
            IterationGate::new(1),
            2,
            8,
            16,
        );
        assert!(degenerate.finished());
    }
}
