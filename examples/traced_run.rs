//! A minimal traced run: a TCP rollout worker streams chunked
//! responses into a served session while the driver grades and
//! consumes them, then the merged telemetry snapshot is rendered as
//! Chrome trace-event JSON — the scripted version of
//! `asyncflow trace --connect HOST:PORT --out trace.json`.
//!
//! Open the output in Perfetto (<https://ui.perfetto.dev>) or
//! `chrome://tracing` for the paper's Fig. 11 timeline built from
//! live spans: one track per process, the lease→chunk→put chain
//! linked by a shared trace id, and a complete per-sample lineage
//! (leased → first/last chunk → reward → advantage → train).
//!
//! ```sh
//! cargo run --release --example traced_run [trace.json]
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};
use asyncflow::rollout::{run_worker, WorkerOptions};
use asyncflow::runtime::{MockEngine, ParamSet, Sampler};
use asyncflow::service::{
    GetBatchReply, GetBatchSpec, PutRow, ServiceClient, Session,
    SessionSpec, TcpJsonlServer,
};
use asyncflow::telemetry::{self, chrome_trace, SpanLog};
use asyncflow::transfer_queue::{Column, TaskSpec, Value};

const N: usize = 16;
const ENGINE_BATCH: usize = 4;
const PROMPT_LEN: usize = 8;
const MAX_LEN: usize = 24;

fn main() -> Result<()> {
    // The demo must trace regardless of ASYNCFLOW_TELEMETRY.
    telemetry::set_enabled(Some(true));

    let session = Arc::new(Session::init_engines(
        SessionSpec {
            storage_units: 1,
            tasks: vec![
                TaskSpec::new("rollout", vec![Column::Prompts]),
                TaskSpec::new("grade", vec![Column::Responses]),
                TaskSpec::new(
                    "train_feed",
                    vec![
                        Column::Responses,
                        Column::Rewards,
                        Column::Advantages,
                    ],
                ),
            ],
        },
        ParamSet::new(0, vec![]),
    )?);
    let server = TcpJsonlServer::bind(session, ("127.0.0.1", 0))?;
    let port = server.port();
    println!(
        "== traced run: {N} prompts through a TCP worker on \
         127.0.0.1:{port}, telemetry on =="
    );

    let coord = ServiceClient::connect(("127.0.0.1", port))?;
    coord.put_batch(
        (0..N)
            .map(|i| {
                PutRow::new(vec![(
                    Column::Prompts,
                    Value::I32s(vec![i as i32 + 1; PROMPT_LEN]),
                )])
            })
            .collect(),
    )?;

    // The worker "process": its own span log, its own socket. The
    // final `push_telemetry` inside `run_worker` ships its spans to
    // the coordinator under the process name "w0".
    let worker = std::thread::spawn(move || {
        telemetry::install_thread_log(Some(Arc::new(
            SpanLog::default(),
        )));
        let client = ServiceClient::connect(("127.0.0.1", port))?;
        let mut engine =
            MockEngine::new(ENGINE_BATCH, PROMPT_LEN, MAX_LEN);
        let mut sampler = Sampler::new(1.0, 32, 11);
        let mut opts = WorkerOptions::new("w0");
        opts.chunk_tokens = 4;
        let report = run_worker(
            &client,
            &mut engine,
            &mut sampler,
            &opts,
            None,
            None,
            &|| false,
        );
        telemetry::install_thread_log(None);
        report
    });

    // Driver loop: grade finished responses (reward + advantage cells
    // complete the lineage chain), then consume `train_feed` — the
    // train-side pop closes each row's lineage and feeds the
    // staleness histogram.
    let grade_spec = GetBatchSpec {
        task: "grade".into(),
        group: 0,
        columns: vec![Column::Responses],
        count: ENGINE_BATCH,
        min: 1,
        timeout_ms: 50,
        consumer: None,
    };
    let train_spec = GetBatchSpec {
        task: "train_feed".into(),
        group: 0,
        columns: vec![Column::Responses, Column::Advantages],
        count: ENGINE_BATCH,
        min: 1,
        timeout_ms: 50,
        consumer: None,
    };
    let mut trained = 0usize;
    let deadline = Instant::now() + Duration::from_secs(60);
    while trained < N {
        if Instant::now() >= deadline {
            bail!("stalled at {trained}/{N} trained rows");
        }
        if let GetBatchReply::Ready(b) = coord.get_batch(&grade_spec)?
        {
            let rows = b
                .indices
                .iter()
                .zip(&b.rows)
                .map(|(idx, row)| {
                    let len = row[0].as_i32s().unwrap().len() as f32;
                    PutRow::at(*idx, vec![
                        (Column::Rewards, Value::F32(len)),
                        (Column::Advantages, Value::F32(len - 1.0)),
                    ])
                })
                .collect();
            coord.put_batch(rows)?;
        }
        match coord.get_batch(&train_spec)? {
            GetBatchReply::Ready(b) => trained += b.indices.len(),
            GetBatchReply::NotReady => {}
            other => bail!("unexpected reply: {other:?}"),
        }
    }
    coord.shutdown()?;
    let report = worker.join().expect("worker thread")?;
    println!(
        "worker w0: {} samples, {} tokens in {} chunks",
        report.samples, report.tokens, report.chunks
    );

    // `asyncflow trace --connect` in miniature: pull the merged
    // snapshot and render it for Perfetto.
    let snap = coord.export_telemetry(None)?;
    telemetry::set_enabled(None);
    let out = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "trace.json".into());
    std::fs::write(&out, chrome_trace(&snap).to_string().as_bytes())
        .with_context(|| format!("writing {out}"))?;

    for p in &snap.procs {
        println!("  process {:<12} {} spans", p.proc, p.spans.len());
    }
    let complete =
        snap.lineage.iter().filter(|r| r.complete()).count();
    println!(
        "  lineage: {complete}/{} rows complete; wrote {out}",
        snap.lineage.len()
    );
    assert!(
        snap.procs
            .iter()
            .any(|p| p.proc == "w0" && !p.spans.is_empty()),
        "worker process pushed no spans"
    );
    assert_eq!(complete, N, "every trained row has a complete chain");

    server.stop();
    Ok(())
}
