//! Elastic streaming rollout demo: two rollout workers — one in-process,
//! one attached over the real TCP transport — lease prompts from the
//! same session and stream chunked generations back. Mid-run the TCP
//! worker is killed; its lease expires and the survivor inherits the
//! unfinished prompts (requeued exactly once), so the run still drains
//! every sample. Downstream consumption starts on the first finished
//! row, long before the slowest generation completes.
//!
//! ```sh
//! cargo run --release --example elastic_rollout
//! ```

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use asyncflow::rollout::{run_worker, WorkerOptions, WorkerReport};
use asyncflow::runtime::{MockEngine, ParamSet, Sampler};
use asyncflow::service::{
    GetBatchReply, GetBatchSpec, PutRow, ServiceClient, Session,
    SessionSpec, TcpJsonlServer,
};
use asyncflow::transfer_queue::{Column, TaskSpec, Value};

const PROMPTS: usize = 64;
const BATCH: usize = 8;
const PROMPT_LEN: usize = 8;
const MAX_LEN: usize = 72;

fn worker_opts(name: &str) -> WorkerOptions {
    let mut opts = WorkerOptions::new(name);
    opts.chunk_tokens = 8;
    opts.ttl_ms = 150;
    opts
}

fn main() -> Result<()> {
    let session = Arc::new(Session::init_engines(
        SessionSpec {
            storage_units: 4,
            tasks: vec![
                TaskSpec::new("rollout", vec![Column::Prompts]),
                TaskSpec::new(
                    "collect",
                    vec![Column::Responses, Column::OldLogp],
                ),
            ],
        },
        ParamSet::new(0, vec![]),
    )?);
    let server = TcpJsonlServer::bind(session.clone(), ("127.0.0.1", 0))?;
    println!(
        "== elastic rollout: {PROMPTS} prompts, 1 local + 1 TCP worker \
         (killed mid-run), service on {} ==",
        server.local_addr()
    );

    // Ingest prompts (varying content -> varying response lengths).
    let feeder = ServiceClient::in_proc(session.clone());
    feeder.put_batch(
        (0..PROMPTS)
            .map(|i| {
                PutRow::new(vec![(
                    Column::Prompts,
                    Value::I32s(vec![i as i32 + 1; PROMPT_LEN]),
                )])
            })
            .collect(),
    )?;

    // Local worker: steady, survives the whole run.
    let survivor = {
        let client = ServiceClient::in_proc(session.clone());
        std::thread::spawn(move || -> Result<WorkerReport> {
            let mut engine = MockEngine::new(BATCH, PROMPT_LEN, MAX_LEN);
            engine.token_delay = Duration::from_micros(300);
            let mut sampler = Sampler::new(1.0, 32, 1);
            run_worker(
                &client,
                &mut engine,
                &mut sampler,
                &worker_opts("local-0"),
                None,
                None,
                &|| false,
            )
        })
    };

    // TCP worker: a straggler that gets killed mid-generation.
    let killed = Arc::new(AtomicBool::new(false));
    let victim = {
        let addr = server.local_addr();
        let killed = killed.clone();
        std::thread::spawn(move || -> Result<WorkerReport> {
            let client = ServiceClient::connect(addr)?;
            let mut engine = MockEngine::new(BATCH, PROMPT_LEN, MAX_LEN);
            engine.token_delay = Duration::from_micros(900);
            let mut sampler = Sampler::new(1.0, 32, 2);
            run_worker(
                &client,
                &mut engine,
                &mut sampler,
                &worker_opts("tcp-victim"),
                None,
                None,
                &|| killed.load(Ordering::SeqCst),
            )
        })
    };

    // Kill the TCP worker once it is mid-flight.
    {
        let killed = killed.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(60));
            killed.store(true, Ordering::SeqCst);
        });
    }

    // Drain finished rows as they stream in.
    let consumer = ServiceClient::in_proc(session.clone());
    let spec = GetBatchSpec {
        task: "collect".into(),
        group: 0,
        columns: vec![Column::Responses],
        count: 16,
        min: 1,
        timeout_ms: 50,
    };
    let t0 = Instant::now();
    let mut first: Option<Duration> = None;
    let mut seen = std::collections::HashSet::new();
    while seen.len() < PROMPTS {
        if let GetBatchReply::Ready(batch) = consumer.get_batch(&spec)? {
            first.get_or_insert_with(|| t0.elapsed());
            for idx in batch.indices {
                assert!(seen.insert(idx), "row {idx} served twice");
            }
        }
    }
    let total = t0.elapsed();
    consumer.shutdown()?;

    let s = survivor.join().unwrap()?;
    let v = victim.join().unwrap()?;
    println!(
        "first trainable sample after {:.1}ms; all {PROMPTS} after \
         {:.1}ms (exactly once)",
        first.unwrap().as_secs_f64() * 1e3,
        total.as_secs_f64() * 1e3
    );
    println!(
        "survivor: {} samples, {} chunks; victim before kill: {} samples",
        s.samples, s.chunks, v.samples
    );
    for w in consumer.worker_stats()? {
        println!(
            "worker {:<10} completed={:<3} requeued={:<3} tokens={}",
            w.worker, w.completed_rows, w.requeued_rows, w.generated_tokens
        );
    }
    assert_eq!(
        s.samples + v.samples,
        PROMPTS as u64,
        "conservation: every prompt generated exactly once"
    );
    server.stop();
    Ok(())
}
