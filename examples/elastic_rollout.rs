//! Elastic streaming rollout over a distributed data plane.
//!
//! Topology of the demo (paper §3.2 + §3.3 made literal):
//! * a served session with 4 storage-unit slots;
//! * slots 0 and 1 hosted by **separate storage-unit processes** (this
//!   example re-execs itself twice as unit hosts, same code path as
//!   `asyncflow storage-unit --connect`), slots 2 and 3 stay
//!   coordinator-local — so both the direct-unit path and the
//!   via-coordinator fallback are exercised;
//! * a feeder attached over TCP writes prompt payloads value-first
//!   straight to the owning units (binary frames), then notifies the
//!   metadata-only control plane;
//! * two rollout workers — one in-process, one over TCP — lease
//!   prompts and stream chunked generations; the TCP worker is killed
//!   mid-run and the survivor inherits its requeued prompts;
//! * a TCP consumer drains finished rows with `get_batch_meta` +
//!   direct binary fetches, payload bytes bypassing the coordinator
//!   socket.
//!
//! ```sh
//! cargo run --release --example elastic_rollout
//! ```

use std::process::{Child, Command};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};
use asyncflow::rollout::{run_worker, WorkerOptions, WorkerReport};
use asyncflow::runtime::{MockEngine, ParamSet, Sampler};
use asyncflow::service::{
    GetBatchReply, GetBatchSpec, PutRow, ServiceClient, Session,
    SessionSpec, TcpJsonlServer,
};
use asyncflow::transfer_queue::{
    Column, StorageUnit, TaskSpec, UnitServer, Value,
};

const PROMPTS: usize = 64;
const BATCH: usize = 8;
const PROMPT_LEN: usize = 8;
const MAX_LEN: usize = 72;
const REMOTE_UNITS: usize = 2;

const COORD_ENV: &str = "ELASTIC_ROLLOUT_UNIT_COORD";
const SLOT_ENV: &str = "ELASTIC_ROLLOUT_UNIT_SLOT";

fn worker_opts(name: &str) -> WorkerOptions {
    let mut opts = WorkerOptions::new(name);
    opts.chunk_tokens = 8;
    opts.ttl_ms = 150;
    opts
}

/// Child mode: host one storage-unit shard and serve until killed —
/// the same flow as `asyncflow storage-unit --connect`.
fn run_unit_host(coordinator: &str, slot: usize) -> Result<()> {
    let client = ServiceClient::connect_relay(coordinator)?;
    let store = Arc::new(StorageUnit::new(slot));
    let server = UnitServer::bind(store, ("127.0.0.1", 0))?;
    client
        .attach_unit(slot, &format!("127.0.0.1:{}", server.port()))
        .context("registering with the coordinator")?;
    server.join();
    Ok(())
}

/// Spawn this example again as a unit-host process for `slot`.
fn spawn_unit_host(coordinator: &str, slot: usize) -> Result<Child> {
    Command::new(std::env::current_exe()?)
        .env(COORD_ENV, coordinator)
        .env(SLOT_ENV, slot.to_string())
        .spawn()
        .context("spawning storage-unit host process")
}

/// Kill-on-drop guard so the unit-host children never outlive the demo,
/// whichever way it exits (assert, bail, or clean return).
struct UnitHosts(Vec<Child>);

impl Drop for UnitHosts {
    fn drop(&mut self) {
        for child in &mut self.0 {
            child.kill().ok();
            child.wait().ok();
        }
    }
}

fn main() -> Result<()> {
    if let Ok(coordinator) = std::env::var(COORD_ENV) {
        let slot: usize = std::env::var(SLOT_ENV)
            .context("unit host needs a slot")?
            .parse()?;
        return run_unit_host(&coordinator, slot);
    }

    let session = Arc::new(Session::init_engines(
        SessionSpec {
            storage_units: 4,
            tasks: vec![
                TaskSpec::new("rollout", vec![Column::Prompts]),
                TaskSpec::new(
                    "collect",
                    vec![Column::Responses, Column::OldLogp],
                ),
            ],
        },
        ParamSet::new(0, vec![]),
    )?);
    let server = TcpJsonlServer::bind(session.clone(), ("127.0.0.1", 0))?;
    let addr = server.local_addr();
    println!(
        "== elastic rollout on a distributed data plane: {PROMPTS} \
         prompts, {REMOTE_UNITS} storage-unit processes + 2 local \
         slots, 1 local + 1 TCP worker (killed mid-run), service on \
         {addr} =="
    );

    // Separate storage-unit processes claim slots 0 and 1.
    let unit_hosts = UnitHosts(
        (0..REMOTE_UNITS)
            .map(|slot| spawn_unit_host(&addr.to_string(), slot))
            .collect::<Result<_>>()?,
    );
    let admin = ServiceClient::in_proc(session.clone());
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let attached = admin
            .stats()?
            .units
            .iter()
            .filter(|u| u.endpoint.is_some())
            .count();
        if attached >= REMOTE_UNITS {
            break;
        }
        if Instant::now() > deadline {
            bail!("storage-unit processes failed to attach in time");
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    println!(
        "   storage units attached: {:?}",
        admin
            .stats()?
            .units
            .iter()
            .map(|u| u.endpoint.clone().unwrap_or_else(|| "local".into()))
            .collect::<Vec<_>>()
    );

    // Feeder over TCP in direct mode: prompt payloads go value-first
    // to the owning units; the coordinator socket sees metadata only.
    let feeder = ServiceClient::connect(addr)?;
    feeder.refresh_topology()?;
    feeder.put_batch(
        (0..PROMPTS)
            .map(|i| {
                PutRow::new(vec![(
                    Column::Prompts,
                    Value::I32s(vec![i as i32 + 1; PROMPT_LEN]),
                )])
            })
            .collect(),
    )?;

    // Local worker: steady, survives the whole run.
    let survivor = {
        let client = ServiceClient::in_proc(session.clone());
        std::thread::spawn(move || -> Result<WorkerReport> {
            let mut engine = MockEngine::new(BATCH, PROMPT_LEN, MAX_LEN);
            engine.token_delay = Duration::from_micros(300);
            let mut sampler = Sampler::new(1.0, 32, 1);
            run_worker(
                &client,
                &mut engine,
                &mut sampler,
                &worker_opts("local-0"),
                None,
                None,
                &|| false,
            )
        })
    };

    // TCP worker: a straggler that gets killed mid-generation.
    let killed = Arc::new(AtomicBool::new(false));
    let victim = {
        let killed = killed.clone();
        std::thread::spawn(move || -> Result<WorkerReport> {
            let client = ServiceClient::connect(addr)?;
            let mut engine = MockEngine::new(BATCH, PROMPT_LEN, MAX_LEN);
            engine.token_delay = Duration::from_micros(900);
            let mut sampler = Sampler::new(1.0, 32, 2);
            run_worker(
                &client,
                &mut engine,
                &mut sampler,
                &worker_opts("tcp-victim"),
                None,
                None,
                &|| killed.load(Ordering::SeqCst),
            )
        })
    };

    // Kill the TCP worker once it is mid-flight.
    {
        let killed = killed.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(60));
            killed.store(true, Ordering::SeqCst);
        });
    }

    // Drain finished rows as they stream in — a TCP consumer in direct
    // mode: `get_batch_meta` for placement, payload bytes off the unit
    // sockets, coordinator fallback for the local slots.
    let consumer = ServiceClient::connect(addr)?;
    consumer.refresh_topology()?;
    let spec = GetBatchSpec {
        task: "collect".into(),
        group: 0,
        columns: vec![Column::Responses],
        count: 16,
        min: 1,
        timeout_ms: 50,
        consumer: None,
    };
    let t0 = Instant::now();
    let mut first: Option<Duration> = None;
    let mut seen = std::collections::HashSet::new();
    while seen.len() < PROMPTS {
        if let GetBatchReply::Ready(batch) = consumer.get_batch(&spec)? {
            first.get_or_insert_with(|| t0.elapsed());
            for idx in batch.indices {
                assert!(seen.insert(idx), "row {idx} served twice");
            }
        }
    }
    let total = t0.elapsed();
    consumer.shutdown()?;

    let s = survivor.join().unwrap()?;
    let v = victim.join().unwrap()?;
    println!(
        "first trainable sample after {:.1}ms; all {PROMPTS} after \
         {:.1}ms (exactly once)",
        first.unwrap().as_secs_f64() * 1e3,
        total.as_secs_f64() * 1e3
    );
    println!(
        "survivor: {} samples, {} chunks; victim before kill: {} samples",
        s.samples, s.chunks, v.samples
    );
    for w in consumer.worker_stats()? {
        println!(
            "worker {:<10} completed={:<3} requeued={:<3} tokens={}",
            w.worker, w.completed_rows, w.requeued_rows, w.generated_tokens
        );
    }
    let stats = consumer.stats()?;
    let mut remote_written = 0u64;
    let mut remote_read = 0u64;
    for u in &stats.units {
        let place = u
            .endpoint
            .clone()
            .map(|e| format!("unit-process@{e}"))
            .unwrap_or_else(|| "coordinator-local".into());
        println!(
            "unit {:<2} {place:<28} rows={:<4} remote_written={}B \
             remote_read={}B",
            u.unit, u.rows, u.remote_bytes_written, u.remote_bytes_read
        );
        remote_written += u.remote_bytes_written;
        remote_read += u.remote_bytes_read;
    }
    if let Some((sent, received)) = consumer.wire_bytes() {
        println!(
            "consumer coordinator socket: {}B out / {}B in (metadata + \
             fallback only)",
            sent, received
        );
    }
    assert_eq!(
        s.samples + v.samples,
        PROMPTS as u64,
        "conservation: every prompt generated exactly once"
    );
    assert!(
        remote_written > 0,
        "prompt/response payloads must land on the unit processes"
    );
    assert!(
        remote_read > 0,
        "payload reads must flow over the unit sockets"
    );

    drop(unit_hosts);
    server.stop();
    Ok(())
}
