//! Cluster-scale scalability study (the paper's Fig. 10 workflow):
//! sweep cluster sizes for both models and both paradigms, print the
//! throughput table, speedups, and scaling linearity.
//!
//! ```sh
//! cargo run --release --example simulate_cluster
//! ```

use asyncflow::benchkit::Table;
use asyncflow::planner::{CostModel, DeviceSpec, LlmSpec};
use asyncflow::simulator::{simulate, Mode, SimConfig};
use asyncflow::util::stats::linreg_slope;

fn main() {
    let clusters = [32usize, 64, 128, 256, 512, 1024];
    for model in [LlmSpec::qwen_7b(), LlmSpec::qwen_32b()] {
        let cost = CostModel::new(DeviceSpec::ascend_910b(), model.clone());
        println!("\n== {} ==", model.name);
        let mut table = Table::new(&[
            "NPUs",
            "verl (samp/s)",
            "AsyncFlow (samp/s)",
            "speedup",
        ]);
        let mut log_devs = Vec::new();
        let mut log_thr = Vec::new();
        for &devices in &clusters {
            if devices / 2 < cost.model.min_devices() {
                continue; // model does not fit a split this small
            }
            let mut verl_cfg = SimConfig::defaults(devices, Mode::Colocated);
            let mut af_cfg =
                SimConfig::defaults(devices, Mode::SeparatedAsync);
            for c in [&mut verl_cfg, &mut af_cfg] {
                c.iterations = 10;
                c.rollout_instance_devices =
                    cost.model.min_devices().next_power_of_two().max(8);
                c.train_instance_devices = c.rollout_instance_devices;
            }
            let verl = simulate(&verl_cfg, &cost);
            let af = simulate(&af_cfg, &cost);
            let sv = verl.throughput_samples_per_s();
            let sa = af.throughput_samples_per_s();
            table.row(&[
                devices.to_string(),
                format!("{sv:.2}"),
                format!("{sa:.2}"),
                format!("{:.2}x", sa / sv),
            ]);
            log_devs.push((devices as f64).ln());
            log_thr.push(sa.ln());
        }
        print!("{}", table.render());
        if log_devs.len() >= 2 {
            println!(
                "AsyncFlow scaling linearity (log-log slope): {:.2}",
                linreg_slope(&log_devs, &log_thr)
            );
        }
    }
    println!(
        "\nPaper reference: avg 1.59x over verl, peak 2.03x (7B@256), \
         linearity 0.65/0.88 at 16x growth."
    );
}
