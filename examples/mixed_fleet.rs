//! Mixed engine fleet with hedge routing.
//!
//! Topology of the demo:
//! * a served session with hedge routing configured
//!   (`asyncflow serve --routing hedge` in CLI terms);
//! * three rollout-worker **processes** attached over TCP (this example
//!   re-execs itself, the same flow as `asyncflow rollout-worker
//!   --connect host:port --engine-tags ...`): two fast engines tagged
//!   `fast-cheap` and one straggler tagged `slow-accurate` decoding at
//!   20ms/token;
//! * the capability registry learns each engine's geometry and speed
//!   class from the tags riding its lease polls;
//! * once the straggler's silence exceeds the fleet's hedge budget, an
//!   idle fast peer inherits its undone rows as a duplicate lease, the
//!   first finisher commits, and the loser is revoked — every prompt
//!   is served downstream exactly once.
//!
//! ```sh
//! cargo run --release --example mixed_fleet
//! ```

use std::collections::HashSet;
use std::process::{Child, Command};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};
use asyncflow::fleet::{EngineSpec, FleetOptions, RoutingPolicy};
use asyncflow::rollout::{run_worker, WorkerOptions};
use asyncflow::runtime::{MockEngine, ParamSet, Sampler};
use asyncflow::service::{
    GetBatchReply, GetBatchSpec, PutRow, ServiceClient, Session,
    SessionSpec, TcpJsonlServer,
};
use asyncflow::transfer_queue::{Column, TaskSpec, Value};

const PROMPTS: usize = 32;
const PROMPT_LEN: usize = 12;
const MAX_LEN: usize = 44;

const COORD_ENV: &str = "MIXED_FLEET_COORD";
const ROLE_ENV: &str = "MIXED_FLEET_ROLE";

/// Child mode: one rollout-worker process, fast or slow, mirroring
/// `asyncflow rollout-worker --connect <coord> --engine-tags <tags>`.
fn run_fleet_worker(coordinator: &str, role: &str) -> Result<()> {
    let client = ServiceClient::connect(coordinator)?;
    let (batch, delay, tags) = match role {
        "slow" => (4, Duration::from_millis(20), "slow-accurate,mock"),
        _ => (8, Duration::ZERO, "fast-cheap,mock"),
    };
    let mut engine = MockEngine::new(batch, PROMPT_LEN, MAX_LEN);
    engine.token_delay = delay;
    let mut sampler = Sampler::new(1.0, 32, 3);
    let mut opts = WorkerOptions::new(format!("{role}-{}", std::process::id()));
    opts.chunk_tokens = 4;
    opts.ttl_ms = 5000;
    opts.poll_ms = 20;
    opts.engine_tags = EngineSpec::parse_tags(tags);
    run_worker(
        &client,
        &mut engine,
        &mut sampler,
        &opts,
        None,
        None,
        &|| false,
    )?;
    Ok(())
}

fn spawn_fleet_worker(coordinator: &str, role: &str) -> Result<Child> {
    Command::new(std::env::current_exe()?)
        .env(COORD_ENV, coordinator)
        .env(ROLE_ENV, role)
        .spawn()
        .context("spawning rollout-worker process")
}

/// Kill-on-drop guard so worker processes never outlive the demo.
struct Fleet(Vec<Child>);

impl Drop for Fleet {
    fn drop(&mut self) {
        for child in &mut self.0 {
            child.kill().ok();
            child.wait().ok();
        }
    }
}

fn main() -> Result<()> {
    if let Ok(coordinator) = std::env::var(COORD_ENV) {
        let role = std::env::var(ROLE_ENV).unwrap_or_else(|_| "fast".into());
        return run_fleet_worker(&coordinator, &role);
    }

    let session = Arc::new(Session::init_engines(
        SessionSpec {
            storage_units: 2,
            tasks: vec![
                TaskSpec::new("rollout", vec![Column::Prompts]),
                TaskSpec::new(
                    "collect",
                    vec![Column::Responses, Column::OldLogp],
                ),
            ],
        },
        ParamSet::new(0, vec![]),
    )?);
    session.set_fleet_options(FleetOptions {
        policy: RoutingPolicy::Hedge,
        hedge_factor: 0.5,
        hedge_min_ms: 25,
        hedge_min_samples: 4,
        ..FleetOptions::default()
    });
    let server = TcpJsonlServer::bind(session.clone(), ("127.0.0.1", 0))?;
    let addr = server.local_addr();
    println!(
        "== mixed fleet under hedge routing: {PROMPTS} prompts, 2 fast \
         + 1 slow worker processes, service on {addr} =="
    );

    let mut fleet = Fleet(Vec::new());
    for role in ["fast", "fast", "slow"] {
        fleet.0.push(spawn_fleet_worker(&addr.to_string(), role)?);
    }

    // The registry doubles as the readiness signal: every worker's
    // first (empty) poll lands its capability spec.
    let admin = ServiceClient::in_proc(session.clone());
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let f = admin.stats()?.fleet.expect("fleet stats");
        if f.engines.iter().filter(|e| e.spec_reported).count() >= 3 {
            break;
        }
        if Instant::now() > deadline {
            bail!("worker processes failed to attach in time");
        }
        std::thread::sleep(Duration::from_millis(20));
    }

    // All three workers are parked in long-polls now, so the straggler
    // is guaranteed a share of the prompts when they land.
    let feeder = ServiceClient::connect(addr)?;
    feeder.put_batch(
        (0..PROMPTS)
            .map(|i| {
                PutRow::new(vec![(
                    Column::Prompts,
                    Value::I32s(vec![i as i32 + 1; PROMPT_LEN]),
                )])
            })
            .collect(),
    )?;

    let spec = GetBatchSpec {
        task: "collect".into(),
        group: 0,
        columns: vec![Column::Responses],
        count: 16,
        min: 1,
        timeout_ms: 50,
        consumer: None,
    };
    let t0 = Instant::now();
    let mut seen = HashSet::new();
    while seen.len() < PROMPTS {
        if let GetBatchReply::Ready(batch) = feeder.get_batch(&spec)? {
            for idx in batch.indices {
                assert!(seen.insert(idx), "row {idx:?} served twice");
            }
        }
    }
    let total = t0.elapsed();
    feeder.shutdown()?;

    // The closed prompt stream winds the worker processes down cleanly.
    for child in &mut fleet.0 {
        let status = child.wait()?;
        if !status.success() {
            bail!("worker process exited with {status}");
        }
    }

    let f = admin.stats()?.fleet.expect("fleet stats");
    println!(
        "\nall {PROMPTS} prompts served exactly once in {:.1}ms under \
         routing={} (hedge budget {:.1}ms, chunk p95 {:.1}ms)",
        total.as_secs_f64() * 1e3,
        f.routing,
        f.hedge_budget_ms,
        f.chunk_time_p95_ms
    );
    for e in &f.engines {
        println!(
            "engine {:<12} kind={:<5} speed={:<8} geometry={}x{}->{} \
             tags=[{}] chunks={} tokens={}",
            e.worker,
            e.spec.kind,
            e.spec.speed.name(),
            e.spec.batch,
            e.spec.prompt_len,
            e.spec.max_len,
            e.spec.tags.join(","),
            e.chunks,
            e.tokens
        );
    }
    println!(
        "hedges issued={} rows won by duplicate={} by primary={} \
         duplicated tokens={}",
        f.hedges_issued,
        f.hedge_rows_won_by_duplicate,
        f.hedge_rows_won_by_primary,
        f.duplicated_tokens
    );

    assert!(f.hedges_issued >= 1, "the straggler was never hedged");
    assert!(
        f.engines.iter().any(|e| e.spec.speed.name() == "fast")
            && f.engines.iter().any(|e| e.spec.speed.name() == "slow"),
        "both speed classes visible in the registry"
    );

    server.stop();
    Ok(())
}
