//! Resource planning demo (paper §4.3): search device splits / instance
//! sizes / micro-batches for several cluster sizes and report the chosen
//! configuration, comparing against naive 50/50 splits.
//!
//! ```sh
//! cargo run --release --example plan_resources
//! ```

use asyncflow::benchkit::Table;
use asyncflow::planner::{
    plan, CostModel, DeviceSpec, LlmSpec, PlanRequest,
};
use asyncflow::simulator::{simulate, Mode, SimConfig};

fn main() {
    for model in [LlmSpec::qwen_7b(), LlmSpec::qwen_32b()] {
        let cost = CostModel::new(DeviceSpec::ascend_910b(), model.clone());
        println!("\n== planning for {} ==", model.name);
        let mut table = Table::new(&[
            "NPUs",
            "rollout frac",
            "inst (r/t)",
            "micro-batch",
            "planned samp/s",
            "naive 50/50 samp/s",
            "gain",
        ]);
        for devices in [64usize, 128, 256, 512] {
            if devices / 2 < cost.model.min_devices() {
                continue;
            }
            let req = PlanRequest::new(devices);
            let p = plan(&req, &cost);

            // naive baseline: 50/50 split, 8-device instances, mb=16
            let mut naive =
                SimConfig::defaults(devices, Mode::SeparatedAsync);
            naive.iterations = req.sim_iterations;
            naive.global_batch = req.global_batch;
            naive.rollout_instance_devices =
                cost.model.min_devices().next_power_of_two().max(8);
            naive.train_instance_devices = naive.rollout_instance_devices;
            let naive_result = simulate(&naive, &cost);
            let naive_thr = naive_result.throughput_samples_per_s();

            table.row(&[
                devices.to_string(),
                format!("{:.3}", p.best.rollout_fraction),
                format!(
                    "{}/{}",
                    p.best.rollout_instance_devices,
                    p.best.train_instance_devices
                ),
                p.best.micro_batch.to_string(),
                format!("{:.2}", p.best.throughput_samples_per_s),
                format!("{naive_thr:.2}"),
                format!(
                    "{:+.1}%",
                    100.0
                        * (p.best.throughput_samples_per_s / naive_thr
                            - 1.0)
                ),
            ]);
        }
        print!("{}", table.render());
    }
}
