//! Quickstart: the smallest end-to-end AsyncFlow run.
//!
//! Uses the real three-layer stack if `make artifacts` has been run
//! (tiny preset), otherwise falls back to the mock backend. Runs a few
//! GRPO iterations through the full TransferQueue pipeline and prints
//! the reward curve.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use asyncflow::config::RlConfig;
use asyncflow::coordinator::Trainer;
use asyncflow::launcher::build_engines;
use asyncflow::runtime::{default_artifact_dir, Manifest};

fn main() -> Result<()> {
    // Prefer the real PJRT backend when artifacts exist.
    let have_artifacts = Manifest::load(default_artifact_dir()).is_ok();
    let cfg = RlConfig {
        iterations: if have_artifacts { 3 } else { 5 },
        global_batch: 16,
        group_size: 4,
        rollout_workers: 2,
        staleness: 1,
        ..RlConfig::default()
    };
    println!(
        "== AsyncFlow quickstart ({} backend) ==",
        if have_artifacts { "xla-pjrt" } else { "mock" }
    );
    let (engines, batch) = build_engines(&cfg, !have_artifacts)?;
    println!(
        "engine batch={batch}, {} rollout workers, staleness={}",
        cfg.rollout_workers, cfg.staleness
    );

    let report = Trainer::new(cfg, engines)?.run()?;

    println!("\niterations      : {}", report.iterations);
    println!("samples trained : {}", report.samples_trained);
    println!("tokens trained  : {}", report.tokens_trained);
    println!("wall time       : {:.2}s", report.wall_time_s);
    println!(
        "throughput      : {:.2} samples/s, {:.0} tokens/s",
        report.throughput_samples_per_s(),
        report.throughput_tokens_per_s()
    );
    if let Some(s) = report.metrics.series("reward") {
        println!(
            "reward          : mean {:.3}, tail-25% {:.3} (n={})",
            s.mean(),
            report.final_reward,
            s.points.len()
        );
    }
    println!("\nworker utilization over the run:");
    let horizon = report.timeline.horizon();
    for w in report.timeline.workers() {
        println!(
            "  {w:<12} {:.0}%",
            100.0 * report.timeline.utilization(&w, horizon)
        );
    }
    Ok(())
}
