//! Quickstart: the smallest end-to-end AsyncFlow run, driven through the
//! service API.
//!
//! Uses the real three-layer stack if `make artifacts` has been run
//! (tiny preset), otherwise falls back to the mock backend. Runs a few
//! GRPO iterations through the full TransferQueue pipeline — every data
//! exchange goes through a `ServiceClient` over the in-process transport
//! (the same verbs remote workers use against `asyncflow serve`) — and
//! prints live queue stats plus the reward curve.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::time::Duration;

use anyhow::Result;
use asyncflow::config::RlConfig;
use asyncflow::coordinator::Trainer;
use asyncflow::launcher::build_engines;
use asyncflow::runtime::{default_artifact_dir, Manifest};

fn main() -> Result<()> {
    // Prefer the real PJRT backend when artifacts exist.
    let have_artifacts = Manifest::load(default_artifact_dir()).is_ok();
    let cfg = RlConfig {
        iterations: if have_artifacts { 3 } else { 5 },
        global_batch: 16,
        group_size: 4,
        rollout_workers: 2,
        staleness: 1,
        ..RlConfig::default()
    };
    println!(
        "== AsyncFlow quickstart ({} backend, service API) ==",
        if have_artifacts { "xla-pjrt" } else { "mock" }
    );
    let (engines, batch) = build_engines(&cfg, !have_artifacts)?;
    println!(
        "engine batch={batch}, {} rollout workers, staleness={}",
        cfg.rollout_workers, cfg.staleness
    );

    // The Trainer's workers exchange all data through ServiceClient over
    // the in-process transport; grab our own client on the same session
    // to watch the run live — exactly what a remote monitor would do
    // against `asyncflow serve`.
    let trainer = Trainer::new(cfg, engines)?;
    let client = trainer.client();
    let run = std::thread::spawn(move || trainer.run());
    while !run.is_finished() {
        std::thread::sleep(Duration::from_millis(200));
        if let Ok(stats) = client.stats() {
            let depths: Vec<String> = stats
                .tasks
                .iter()
                .map(|t| format!("{}:{}", t.name, t.ready))
                .collect();
            println!(
                "[stats] weights v{} | resident {} | ready {}",
                stats.param_version,
                stats.resident_rows,
                depths.join(" ")
            );
        }
    }
    let report = run.join().expect("trainer thread panicked")?;

    println!("\niterations      : {}", report.iterations);
    println!("samples trained : {}", report.samples_trained);
    println!("tokens trained  : {}", report.tokens_trained);
    println!("wall time       : {:.2}s", report.wall_time_s);
    println!(
        "throughput      : {:.2} samples/s, {:.0} tokens/s",
        report.throughput_samples_per_s(),
        report.throughput_tokens_per_s()
    );
    if let Some(s) = report.metrics.series("reward") {
        println!(
            "reward          : mean {:.3}, tail-25% {:.3} (n={})",
            s.mean(),
            report.final_reward,
            s.points.len()
        );
    }
    println!("\nworker utilization over the run:");
    let horizon = report.timeline.horizon();
    for w in report.timeline.workers() {
        println!(
            "  {w:<12} {:.0}%",
            100.0 * report.timeline.utilization(&w, horizon)
        );
    }
    Ok(())
}
