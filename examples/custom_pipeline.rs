//! A custom RL dataflow on the stage-graph pipeline API: best-of-n
//! rejection sampling with the reward stage running in a **separate
//! process over TCP**.
//!
//! The graph (declared as a `PipelineSpec`, no bespoke worker wiring):
//!
//! ```text
//!  feeder ─▶ rollout(×2, lease verbs) ─▶ reference ─▶ update(driver)
//!                 └──▶ [reward: TCP-attached process] ─▶ filter(top-k)
//! ```
//!
//! * The parent process runs feeder / rollout / reference / filter /
//!   update through a `PipelineRunner` and serves the session over
//!   TCP.
//! * The **only** reward grader is a child process (this example
//!   re-execs itself) attached with `run_remote_stage` — the exact
//!   code path of `asyncflow stage --connect HOST:PORT --stage
//!   reward`. If it never attached, the run could not finish: the
//!   grading really happens out-of-process.
//! * The filter keeps each group's top-k rollouts by reward and emits
//!   `Advantages = 1.0` for survivors only, so the update driver
//!   trains on k of G rollouts per prompt — rejection sampling as a
//!   spec, not new plumbing.
//!
//! ```sh
//! cargo run --release --example custom_pipeline
//! ```

use std::process::{Child, Command};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};
use asyncflow::coordinator::IterationGate;
use asyncflow::data::MathTaskGen;
use asyncflow::exec::Shutdown;
use asyncflow::pipeline::{
    builtin_stage, run_remote_stage, FilterTopK, PipelineRunner,
    PipelineSpec, PromptFeeder, ReferenceLogp, RolloutNode, Stage,
    StageNode, TrainPlan, TrainPublish,
};
use asyncflow::rollout::WorkerOptions;
use asyncflow::runtime::{
    MockEngine, ParamSet, PolicyEngine, TrainEngine,
};
use asyncflow::service::{
    ServiceClient, Session, SessionSpec, TcpJsonlServer,
};

const ITERATIONS: usize = 2;
const GLOBAL_BATCH: usize = 16;
const GROUP_SIZE: usize = 4;
const SURVIVORS: usize = 2;
const BATCH: usize = 8;
const PROMPT_LEN: usize = 16;
const MAX_LEN: usize = 48;

const ADDR_ENV: &str = "CUSTOM_PIPELINE_REWARD_ADDR";

/// Child mode: the TCP-attached reward grader — the same flow as
/// `asyncflow stage --connect HOST:PORT --stage reward`.
fn run_reward_process(addr: &str) -> Result<()> {
    let client = ServiceClient::connect(addr)?;
    let (input, mut stage) =
        builtin_stage("reward", GROUP_SIZE, SURVIVORS)?;
    let metrics = run_remote_stage(
        &client,
        "reward-tcp",
        Some(&input),
        stage.as_mut(),
        &Shutdown::new(),
    )?;
    // The reward series lives in this process, not the coordinator.
    if let Some(s) = metrics.series("reward") {
        println!(
            "[reward-tcp] graded {} rollouts, mean reward {:.3}",
            s.points.len(),
            s.mean()
        );
    }
    Ok(())
}

/// Kill-on-drop guard so the child never outlives the demo.
struct RewardProcess(Child);

impl Drop for RewardProcess {
    fn drop(&mut self) {
        self.0.kill().ok();
        self.0.wait().ok();
    }
}

fn mock_policy() -> Result<Box<dyn PolicyEngine>> {
    Ok(Box::new(MockEngine::new(BATCH, PROMPT_LEN, MAX_LEN)))
}

fn main() -> Result<()> {
    if let Ok(addr) = std::env::var(ADDR_ENV) {
        return run_reward_process(&addr);
    }

    // The served session carries the standard task graph minus the
    // GRPO advantage task (nothing consumes it in this graph — it
    // would read as a stalled consumer in the liveness stats); the
    // spec adds the best-of-n "filter" task on top.
    let mut session_spec = SessionSpec::grpo();
    session_spec.tasks.retain(|t| t.name != "advantage");
    let session = Arc::new(Session::init_engines(
        session_spec,
        ParamSet::new(0, vec![]),
    )?);
    let server = TcpJsonlServer::bind(session.clone(), ("127.0.0.1", 0))?;
    let addr = server.local_addr();
    println!(
        "== best-of-n rejection sampling as a PipelineSpec: \
         {ITERATIONS} iterations, {GLOBAL_BATCH} rollouts/iter in \
         groups of {GROUP_SIZE}, top-{SURVIVORS} survive; reward stage \
         in a separate process via {addr} =="
    );

    let reward_child = RewardProcess(
        Command::new(std::env::current_exe()?)
            .env(ADDR_ENV, addr.to_string())
            .spawn()
            .context("spawning the reward stage process")?,
    );

    let gate = IterationGate::new(1);
    // The filter's input contract carries its own task declaration
    // (readiness gated on RefLogp so rejected rollouts can be GC'd).
    let mut spec =
        PipelineSpec::new().task(FilterTopK::input().task_decl());

    // Feeder source (staleness-gated prompt ingest).
    {
        let gate = gate.clone();
        spec = spec.node(StageNode::source(
            "feeder",
            Box::new(move || {
                Ok(Box::new(PromptFeeder::new(
                    MathTaskGen::new(0, PROMPT_LEN),
                    gate,
                    ITERATIONS,
                    GLOBAL_BATCH,
                    GROUP_SIZE,
                )) as Box<dyn Stage>)
            }),
        ));
    }
    // Two elastic rollout workers on the lease verbs.
    for r in 0..2u64 {
        let mut opts = WorkerOptions::new(format!("rollout-{r}"));
        opts.lease_rows = BATCH;
        spec = spec.node(StageNode::rollout(
            format!("rollout-{r}"),
            RolloutNode {
                build: Box::new(mock_policy),
                temperature: 1.0,
                top_k: 32,
                seed: r + 1,
                opts,
            },
        ));
    }
    // Reference scorer.
    spec = spec.node(StageNode::stage(
        "reference",
        Some(ReferenceLogp::input(BATCH)),
        Box::new(|| {
            Ok(Box::new(ReferenceLogp::new(
                mock_policy()?,
                PROMPT_LEN,
                MAX_LEN,
            )) as Box<dyn Stage>)
        }),
    ));
    // NOTE: no in-process reward node — grading happens only in the
    // TCP-attached child process.
    // Best-of-n filter.
    spec = spec.node(StageNode::stage(
        "filter",
        Some(FilterTopK::input().with_batch(BATCH, 1)),
        Box::new(|| {
            Ok(Box::new(FilterTopK::new(GROUP_SIZE, SURVIVORS)?)
                as Box<dyn Stage>)
        }),
    ));
    // Update driver: one train step per iteration (the survivors of
    // each iteration fill exactly one engine batch).
    {
        let gate = gate.clone();
        spec = spec.node(StageNode::driver(
            "update",
            TrainPublish::input(BATCH),
            Box::new(move || {
                Ok(Box::new(TrainPublish::new(
                    Box::new(MockEngine::new(BATCH, PROMPT_LEN, MAX_LEN))
                        as Box<dyn TrainEngine>,
                    gate,
                    TrainPlan {
                        iterations: ITERATIONS as u64,
                        steps_per_iter: (GLOBAL_BATCH / GROUP_SIZE
                            * SURVIVORS
                            / BATCH)
                            as u64,
                        batch: BATCH,
                        prompt_len: PROMPT_LEN,
                        max_len: MAX_LEN,
                        lr: 1e-3,
                    },
                )) as Box<dyn Stage>)
            }),
        ));
    }

    let runner = PipelineRunner::new(ServiceClient::in_proc(session.clone()));
    // Watchdog: if the reward child never attaches the run cannot
    // finish — drain instead of hanging CI forever.
    {
        let shutdown = runner.shutdown_handle();
        let client = ServiceClient::in_proc(session.clone());
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_secs(120));
            if !shutdown.is_triggered() {
                eprintln!("watchdog: draining stalled run");
                shutdown.trigger();
                let _ = client.shutdown();
            }
        });
    }
    let report = runner.run(spec)?;

    let trained = report.metrics.counter("samples_trained");
    let groups = report.metrics.counter("filter_groups");
    let survivors = report.metrics.counter("filter_survivors");
    println!(
        "trained {trained} samples in {:.1}ms: {groups} groups filtered \
         to {survivors} survivors",
        report.wall_time_s * 1e3
    );
    let stats = session.stats()?;
    for t in &stats.tasks {
        println!(
            "  task {:<10} ready={:<4} consumed={:<4} waiting={} \
             oldest_ready={}",
            t.name,
            t.ready,
            t.consumed,
            t.waiting_consumers,
            t.oldest_ready_age_ms
                .map(|ms| format!("{ms}ms"))
                .unwrap_or_else(|| "-".into()),
        );
    }
    assert_eq!(
        trained as usize,
        ITERATIONS * GLOBAL_BATCH / GROUP_SIZE * SURVIVORS,
        "update trained exactly the survivors"
    );
    assert_eq!(survivors, trained, "filter passed exactly the survivors");
    // The filter only ever sees rows that carry a `Rewards` cell, and
    // this process runs NO reward stage — so every one of the
    // 2x16 rollouts reaching the filter proves the TCP-attached child
    // graded it.
    assert_eq!(
        groups as usize,
        ITERATIONS * GLOBAL_BATCH / GROUP_SIZE,
        "every group was fully graded by the TCP-attached reward process"
    );
    println!(
        "OK: all {} rollouts graded out-of-process; top-{SURVIVORS} of \
         each group trained",
        ITERATIONS * GLOBAL_BATCH
    );

    drop(reward_child);
    server.stop();
    Ok(())
}
