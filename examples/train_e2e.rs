//! End-to-end training driver — the repo's headline validation run.
//!
//! Trains the AOT-compiled transformer (see `python/compile/model.py`,
//! presets `tiny`/`small`) with GRPO on synthetic verifiable math tasks
//! for a configurable number of iterations, through the full AsyncFlow
//! stack: TransferQueue streaming via the service API (`ServiceClient`
//! over the in-process transport), multi-worker rollout, delayed
//! parameter updates with one-step staleness, and the Adam train_step
//! artifact executed via PJRT. A monitor thread polls the service
//! `stats` verb for live queue depths. Logs the reward/loss curves and
//! writes them to `target/e2e_metrics.json` + CSVs for EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts                      # tiny preset (default)
//! cargo run --release --example train_e2e -- --iterations 40
//! # larger model:
//! #   (cd python && python -m compile.aot --preset small --out ../artifacts)
//! #   cargo run --release --example train_e2e -- --iterations 200
//! ```

use anyhow::{Context, Result};
use asyncflow::config::RlConfig;
use asyncflow::coordinator::Trainer;
use asyncflow::launcher::build_engines;
use asyncflow::planner::ProfileReport;

fn flag(args: &[String], key: &str) -> Option<String> {
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1).cloned())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let iterations: usize = flag(&args, "--iterations")
        .map(|v| v.parse())
        .transpose()
        .context("--iterations")?
        .unwrap_or(40);
    let staleness: u64 = flag(&args, "--staleness")
        .map(|v| v.parse())
        .transpose()
        .context("--staleness")?
        .unwrap_or(1);

    let cfg = RlConfig {
        iterations,
        global_batch: 32,
        group_size: 4,
        rollout_workers: 3,
        staleness,
        storage_units: 4,
        policy: "token_balanced".into(),
        lr: 1e-3,
        temperature: 0.9,
        top_k: 24,
        ..RlConfig::default()
    };
    let (engines, batch) = build_engines(&cfg, false)
        .context("run `make artifacts` first")?;
    println!(
        "== train_e2e: {iterations} iterations, global_batch={}, \
         engine_batch={batch}, staleness={staleness} ==",
        cfg.global_batch
    );

    // All worker data exchange goes through the service API; keep one
    // client for ourselves and poll live queue stats while training.
    let trainer = Trainer::new(cfg, engines)?;
    let client = trainer.client();
    let run = std::thread::spawn(move || trainer.run());
    while !run.is_finished() {
        std::thread::sleep(std::time::Duration::from_secs(2));
        if run.is_finished() {
            break;
        }
        if let Ok(stats) = client.stats() {
            let depths: Vec<String> = stats
                .tasks
                .iter()
                .map(|t| format!("{}:{}/{}", t.name, t.ready, t.consumed))
                .collect();
            println!(
                "[stats] weights v{} | resident {} | ready/consumed {}",
                stats.param_version,
                stats.resident_rows,
                depths.join(" ")
            );
        }
    }
    let report = run.join().expect("trainer thread panicked")?;

    println!("\n-- results --");
    println!("iterations        : {}", report.iterations);
    println!("samples trained   : {}", report.samples_trained);
    println!("wall time         : {:.1}s", report.wall_time_s);
    println!(
        "throughput        : {:.2} samples/s, {:.0} tokens/s",
        report.throughput_samples_per_s(),
        report.throughput_tokens_per_s()
    );
    for name in ["reward", "loss", "kl", "nll", "response_len"] {
        if let Some(s) = report.metrics.series(name) {
            let head =
                &s.points[..(s.points.len() / 4).max(1)];
            let head_mean: f64 =
                head.iter().map(|p| p.1).sum::<f64>() / head.len() as f64;
            println!(
                "{name:<18}: start {head_mean:+.4} -> tail {:+.4}",
                s.tail_mean(0.25)
            );
        }
    }

    // Per-phase profile (feeds the hybrid cost model calibration).
    let profile = ProfileReport::from_timeline(&report.timeline);
    println!("\n-- phase means (s) --");
    for (phase, mean) in &profile.phase_means {
        println!(
            "{phase:<14}: {mean:.4}  (n={})",
            profile.phase_counts[phase]
        );
    }

    // Export curves for EXPERIMENTS.md.
    std::fs::create_dir_all("target").ok();
    std::fs::write(
        "target/e2e_metrics.json",
        report.metrics.to_json().to_string_pretty(),
    )?;
    for name in ["reward", "loss", "response_len"] {
        std::fs::write(
            format!("target/e2e_{name}.csv"),
            report.metrics.series_csv(name),
        )?;
    }
    println!(
        "\nwrote target/e2e_metrics.json, target/e2e_{{reward,loss,\
         response_len}}.csv"
    );
    Ok(())
}
