//! TransferQueue demo, driven entirely through the service API: many
//! concurrent producers and consumers streaming through the columnar
//! queue over `ServiceClient` — the same verbs (`put_batch`,
//! `get_batch`, `stats`) a remote process would use against
//! `asyncflow serve`, here on the zero-copy in-process transport.
//! Exercises the §3 design: metadata-first reads, write-notification
//! broadcast, per-task consumption isolation, the token-balancing
//! policy, and per-storage-unit occupancy observability.
//!
//! ```sh
//! cargo run --release --example tq_demo
//! ```

use std::sync::Arc;

use anyhow::Result;
use asyncflow::runtime::ParamSet;
use asyncflow::service::{
    GetBatchReply, GetBatchSpec, PutRow, ServiceClient, Session,
    SessionSpec,
};
use asyncflow::transfer_queue::{Column, TaskSpec, TokenBalanced, Value};
use asyncflow::util::rng::Rng;

fn main() -> Result<()> {
    const SAMPLES: usize = 2_000;
    const PRODUCERS: usize = 4;
    const CONSUMER_GROUPS: usize = 3;
    const PUT_CHUNK: usize = 16;

    let session = Arc::new(Session::init_engines(
        SessionSpec {
            storage_units: 4,
            tasks: vec![TaskSpec::new("score", vec![Column::Responses])
                .policy(Box::new(TokenBalanced))],
        },
        ParamSet::new(0, vec![]),
    )?);

    println!(
        "== TransferQueue demo (service API): {PRODUCERS} producers -> \
         {CONSUMER_GROUPS} DP groups, {SAMPLES} samples =="
    );

    // Producers write variable-length "responses" (long-tailed lengths),
    // batch-first: one put_batch round-trip per PUT_CHUNK rows.
    let mut producers = Vec::new();
    for p in 0..PRODUCERS {
        let client = ServiceClient::in_proc(session.clone());
        producers.push(std::thread::spawn(move || -> Result<()> {
            let mut rng = Rng::new(p as u64);
            let mut pending = Vec::with_capacity(PUT_CHUNK);
            for _ in 0..SAMPLES / PRODUCERS {
                let len = (rng.lognormal(4.0, 0.8) as usize).clamp(4, 512);
                pending.push(PutRow::new(vec![(
                    Column::Responses,
                    Value::I32s(vec![1; len]),
                )]));
                if pending.len() == PUT_CHUNK {
                    client.put_batch(std::mem::take(&mut pending))?;
                }
            }
            if !pending.is_empty() {
                client.put_batch(pending)?;
            }
            Ok(())
        }));
    }

    // Consumers pull with the token-balanced policy through get_batch.
    let mut consumers = Vec::new();
    for g in 0..CONSUMER_GROUPS {
        let client = ServiceClient::in_proc(session.clone());
        consumers.push(std::thread::spawn(
            move || -> Result<(usize, usize)> {
                let spec = GetBatchSpec {
                    task: "score".into(),
                    group: g,
                    columns: vec![Column::Responses],
                    count: 16,
                    min: 1,
                    timeout_ms: 50,
                    consumer: None,
                };
                let (mut n, mut tokens) = (0usize, 0usize);
                loop {
                    match client.get_batch(&spec)? {
                        GetBatchReply::Ready(batch) => {
                            for row in &batch.rows {
                                tokens += row[0].as_i32s().unwrap().len();
                                n += 1;
                            }
                        }
                        GetBatchReply::NotReady => continue,
                        GetBatchReply::Leased { .. } => {
                            unreachable!("no consumer lease was requested")
                        }
                        GetBatchReply::Closed => return Ok((n, tokens)),
                    }
                }
            },
        ));
    }

    for h in producers {
        h.join().unwrap()?;
    }
    // Close once every sample has been served (visible via `stats`).
    let monitor = ServiceClient::in_proc(session.clone());
    loop {
        let stats = monitor.stats()?;
        let consumed = stats
            .tasks
            .iter()
            .find(|t| t.name == "score")
            .map_or(0, |t| t.consumed);
        if consumed >= SAMPLES {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    monitor.shutdown()?;

    let mut totals = Vec::new();
    let mut served = 0usize;
    for (g, h) in consumers.into_iter().enumerate() {
        let (n, tokens) = h.join().unwrap()?;
        println!("group {g}: {n} samples, {tokens} tokens");
        served += n;
        totals.push(tokens as f64);
    }
    assert_eq!(served, SAMPLES, "every sample served exactly once");
    let mean = totals.iter().sum::<f64>() / totals.len() as f64;
    let spread = totals
        .iter()
        .map(|t| (t - mean).abs() / mean)
        .fold(0.0f64, f64::max);
    println!(
        "token balance: mean {mean:.0} tokens/group, max spread {:.1}% \
         (token_balanced policy)",
        100.0 * spread
    );
    // Per-storage-unit occupancy/traffic over the service boundary.
    let stats = monitor.stats()?;
    for u in &stats.units {
        println!(
            "unit {}: {} rows resident, {}B written, {}B read",
            u.unit, u.rows, u.bytes_written, u.bytes_read
        );
    }
    println!("resident rows: {}", stats.resident_rows);
    Ok(())
}
