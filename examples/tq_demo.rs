//! TransferQueue standalone demo: many concurrent producers and
//! consumers streaming through the columnar queue, exercising the
//! §3 design — metadata-first reads, write-notification broadcast,
//! per-task consumption isolation, and the token-balancing policy.
//!
//! ```sh
//! cargo run --release --example tq_demo
//! ```

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::Result;
use asyncflow::transfer_queue::{
    Column, TaskSpec, TokenBalanced, TransferQueue, Value,
};
use asyncflow::util::rng::Rng;

fn main() -> Result<()> {
    const SAMPLES: usize = 2_000;
    const PRODUCERS: usize = 4;
    const CONSUMER_GROUPS: usize = 3;

    let tq = TransferQueue::builder()
        .storage_units(4)
        .task(
            TaskSpec::new("score", vec![Column::Responses])
                .policy(Box::new(TokenBalanced)),
        )
        .build();

    println!(
        "== TransferQueue demo: {PRODUCERS} producers -> \
         {CONSUMER_GROUPS} DP groups, {SAMPLES} samples =="
    );

    // Producers write variable-length "responses" (long-tailed lengths).
    let mut producers = Vec::new();
    for p in 0..PRODUCERS {
        let tq = tq.clone();
        producers.push(std::thread::spawn(move || -> Result<()> {
            let mut rng = Rng::new(p as u64);
            for _ in 0..SAMPLES / PRODUCERS {
                let len = (rng.lognormal(4.0, 0.8) as usize).clamp(4, 512);
                tq.put_row(vec![(
                    Column::Responses,
                    Value::I32s(vec![1; len]),
                )])?;
            }
            Ok(())
        }));
    }

    // Consumers pull with the token-balanced policy.
    let consumed = Arc::new(AtomicUsize::new(0));
    let mut consumers = Vec::new();
    for g in 0..CONSUMER_GROUPS {
        let tq = tq.clone();
        let consumed = consumed.clone();
        consumers.push(std::thread::spawn(move || -> (usize, usize) {
            let loader =
                tq.loader("score", g, vec![Column::Responses], 16, 1);
            let (mut n, mut tokens) = (0usize, 0usize);
            while let Some(batch) = loader.next_batch() {
                for row in &batch.rows {
                    tokens += row[0].as_i32s().unwrap().len();
                    n += 1;
                }
                consumed.fetch_add(batch.len(), Ordering::SeqCst);
            }
            (n, tokens)
        }));
    }

    for h in producers {
        h.join().unwrap()?;
    }
    while tq.controller("score").consumed_count() < SAMPLES {
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    tq.close();

    let mut totals = Vec::new();
    for (g, h) in consumers.into_iter().enumerate() {
        let (n, tokens) = h.join().unwrap();
        println!("group {g}: {n} samples, {tokens} tokens");
        totals.push(tokens as f64);
    }
    assert_eq!(consumed.load(Ordering::SeqCst), SAMPLES);
    let mean = totals.iter().sum::<f64>() / totals.len() as f64;
    let spread = totals
        .iter()
        .map(|t| (t - mean).abs() / mean)
        .fold(0.0f64, f64::max);
    println!(
        "token balance: mean {mean:.0} tokens/group, max spread {:.1}% \
         (token_balanced policy)",
        100.0 * spread
    );
    println!(
        "data plane: {} bytes written, {} bytes read, {} rows resident",
        tq.data_plane().total_bytes_written(),
        tq.data_plane().total_bytes_read(),
        tq.resident_rows()
    );
    Ok(())
}
