"""Build-time compile path: L2 JAX model + L1 Pallas kernels + AOT lowering.

Nothing in this package is imported at runtime — ``aot.py`` runs once under
``make artifacts`` and writes HLO text + manifest + initial parameters to
``artifacts/``; the Rust coordinator is self-contained afterwards.
"""
