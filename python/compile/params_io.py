"""Writer for ``artifacts/params.bin`` — the cross-language tensor bundle.

Format (little-endian; mirrored by ``rust/src/runtime/artifacts.rs``):

    magic   b"AFPB"            4 bytes
    version u32                = 1
    count   u32
    per tensor:
      name_len u32, name utf-8 bytes
      dtype    u8   (0 = f32, 1 = i32)
      ndim     u32
      dims     u64 * ndim
      nbytes   u64
      data     raw bytes (C-contiguous, little-endian)
"""

import struct
from typing import Dict

import numpy as np

MAGIC = b"AFPB"
VERSION = 1
DTYPE_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}


def write_params(path: str, tensors: Dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            code = DTYPE_CODES[arr.dtype]
            raw = arr.tobytes()
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BI", code, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<Q", d))
            f.write(struct.pack("<Q", len(raw)))
            f.write(raw)


def read_params(path: str) -> Dict[str, np.ndarray]:
    """Round-trip reader (used by tests only; Rust has its own reader)."""
    out: Dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad magic"
        version, count = struct.unpack("<II", f.read(8))
        assert version == VERSION
        for _ in range(count):
            (name_len,) = struct.unpack("<I", f.read(4))
            name = f.read(name_len).decode("utf-8")
            code, ndim = struct.unpack("<BI", f.read(5))
            dims = [struct.unpack("<Q", f.read(8))[0] for _ in range(ndim)]
            (nbytes,) = struct.unpack("<Q", f.read(8))
            raw = f.read(nbytes)
            dtype = {0: np.float32, 1: np.int32}[code]
            out[name] = np.frombuffer(raw, dtype=dtype).reshape(dims).copy()
    return out
