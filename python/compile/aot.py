"""AOT lowering: JAX (L2 + L1) -> HLO text artifacts for the Rust runtime.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids, so text round-trips cleanly (see
/opt/xla-example/README.md).

Run once per preset (``make artifacts``):

    cd python && python -m compile.aot --preset tiny --out ../artifacts

Outputs:
    <out>/prefill.hlo.txt      (params..., prompt_ids)               -> (last_logits, kv)
    <out>/decode_step.hlo.txt  (params..., kv, pos, token)           -> (logits, kv')
    <out>/logprobs.hlo.txt     (params..., ids)                      -> (logp,)
    <out>/train_step.hlo.txt   (params..., m..., v..., step, ids,
                                adv, old_logp, ref_logp, mask, lr)   -> (params'..., m'..., v'..., step', metrics...)
    <out>/manifest.json        artifact arg/result specs + model config
    <out>/params.bin           initial parameters (ref model == init actor)
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import params_io

METRIC_NAMES = ["loss", "policy_loss", "kl", "nll", "grad_norm"]

# Top-k is baked into the rollout artifact (temperature stays a runtime
# input); EOS/PAD conventions are shared with rust/src/data/mod.rs.
TOP_K = 32

# GRPO/Adam hyper-parameters baked into the train_step HLO (lr stays a
# runtime input so the Rust side can run schedules).
HYPERS = dict(clip_eps=0.2, kl_coef=0.05, beta1=0.9, beta2=0.95,
              eps=1e-8, grad_clip=1.0)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def _shape_struct(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_all(cfg: M.ModelConfig):
    """Lower the four entry points; returns {name: (hlo_text, arg_specs, res_specs)}."""
    names = M.canonical_names(cfg)
    shapes = M.param_shapes(cfg)
    p_structs = tuple(_shape_struct(shapes[n]) for n in names)
    B, P, T, V = cfg.batch, cfg.prompt_len, cfg.max_len, cfg.vocab
    kv_shape = (cfg.n_layers, 2, B, cfg.n_heads, T, cfg.d_head)

    param_specs = [_spec(shapes[n]) for n in names]
    out = {}

    # -- prefill ----------------------------------------------------------
    fn = functools.partial(M.prefill, cfg=cfg)
    low = jax.jit(fn).lower(p_structs, _shape_struct((B, P), jnp.int32))
    out["prefill"] = (
        to_hlo_text(low),
        param_specs + [_spec((B, P), "i32")],
        [_spec((B, V)), _spec(kv_shape)],
    )

    # -- rollout (fused generation loop) ----------------------------------
    fn = functools.partial(M.rollout, cfg=cfg, top_k=TOP_K)
    low = jax.jit(fn).lower(
        p_structs, _shape_struct((B, P), jnp.int32),
        _shape_struct((), jnp.int32), _shape_struct(()))
    out["rollout"] = (
        to_hlo_text(low),
        param_specs + [_spec((B, P), "i32"), _spec((), "i32"), _spec(())],
        [_spec((B, T), "i32"), _spec((B, T - P))],
    )

    # -- decode_step ------------------------------------------------------
    fn = functools.partial(M.decode_step, cfg=cfg)
    low = jax.jit(fn).lower(
        p_structs, _shape_struct(kv_shape),
        _shape_struct((), jnp.int32), _shape_struct((B,), jnp.int32))
    out["decode_step"] = (
        to_hlo_text(low),
        param_specs + [_spec(kv_shape), _spec((), "i32"), _spec((B,), "i32")],
        [_spec((B, V)), _spec(kv_shape)],
    )

    # -- logprobs ---------------------------------------------------------
    fn = functools.partial(M.token_logprobs, cfg=cfg)
    low = jax.jit(fn).lower(p_structs, _shape_struct((B, T), jnp.int32))
    out["logprobs"] = (
        to_hlo_text(low),
        param_specs + [_spec((B, T), "i32")],
        [_spec((B, T - 1))],
    )

    # -- train_step -------------------------------------------------------
    fn = functools.partial(M.train_step, cfg=cfg, **HYPERS)
    low = jax.jit(fn).lower(
        p_structs, p_structs, p_structs, _shape_struct(()),
        _shape_struct((B, T), jnp.int32), _shape_struct((B,)),
        _shape_struct((B, T - 1)), _shape_struct((B, T - 1)),
        _shape_struct((B, T - 1)), _shape_struct(()))
    batch_specs = [
        _spec((B, T), "i32"), _spec((B,)), _spec((B, T - 1)),
        _spec((B, T - 1)), _spec((B, T - 1)), _spec(())]
    out["train_step"] = (
        to_hlo_text(low),
        param_specs * 3 + [_spec(())] + batch_specs,
        param_specs * 3 + [_spec(())] + [_spec(()) for _ in METRIC_NAMES],
    )
    return out


def build(preset: str, out_dir: str, seed: int = 0) -> None:
    cfg = M.PRESETS[preset]
    cfg.validate()
    os.makedirs(out_dir, exist_ok=True)
    names = M.canonical_names(cfg)
    shapes = M.param_shapes(cfg)

    print(f"[aot] preset={preset} params={cfg.param_count():,}")
    artifacts = lower_all(cfg)
    manifest = {
        "preset": preset,
        "model": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_heads": cfg.n_heads, "n_layers": cfg.n_layers,
            "d_ff": cfg.d_ff, "prompt_len": cfg.prompt_len,
            "max_len": cfg.max_len, "batch": cfg.batch,
            "d_head": cfg.d_head, "param_count": cfg.param_count(),
        },
        "hypers": HYPERS,
        "sampling": {"top_k": TOP_K, "eos": M.EOS_ID, "pad": M.PAD_ID},
        "metric_names": METRIC_NAMES,
        "param_names": names,
        "param_shapes": {n: list(shapes[n]) for n in names},
        "artifacts": {},
    }
    for name, (hlo, arg_specs, res_specs) in artifacts.items():
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(hlo)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "args": arg_specs,
            "results": res_specs,
        }
        print(f"[aot] wrote {path} ({len(hlo):,} chars, "
              f"{len(arg_specs)} args -> {len(res_specs)} results)")

    params = M.init_params(cfg, seed=seed)
    params_io.write_params(os.path.join(out_dir, "params.bin"), params)
    print(f"[aot] wrote {out_dir}/params.bin ({len(params)} tensors)")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote {out_dir}/manifest.json")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="tiny", choices=sorted(M.PRESETS))
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    build(args.preset, args.out, seed=args.seed)


if __name__ == "__main__":
    main()
