"""L2 — the GRPO actor model: a decoder-only transformer in pure JAX.

Four entry points get AOT-lowered to HLO text (see ``aot.py``), matching the
four compute phases of the AsyncFlow RL workflow:

  * ``prefill``      — rollout prompt phase: full forward over the padded
                       prompt, emitting last-position logits + a KV cache.
  * ``decode_step``  — rollout decode phase: one token in, logits + updated
                       KV cache out (the Pallas decode-attention hot path).
  * ``logprobs``     — reference / behaviour-policy scoring: per-token
                       log-probabilities over a full trajectory.
  * ``train_step``   — actor update: GRPO clipped-surrogate + KL loss
                       (Pallas fused token-loss kernel), Adam update.

Parameters are a flat dict name -> f32 array; the canonical cross-language
ordering is ``sorted(params)`` and is recorded in the artifact manifest so
the Rust runtime can thread parameter literals positionally.

All attention goes through the L1 Pallas kernels (flash_attention /
decode_attention) so they lower into the same HLO modules.
"""

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import decode_attention, flash_attention, grpo_token_loss

# Flash-attention tile sizes used for every lowering in this repo. 16 keeps
# all preset sequence lengths (multiples of 16) tileable; see DESIGN.md §Perf
# for the VMEM-footprint arithmetic behind the choice.
BLOCK_Q = 16
BLOCK_K = 16


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture + batch geometry baked into each artifact."""

    name: str
    vocab: int
    d_model: int
    n_heads: int
    n_layers: int
    d_ff: int
    prompt_len: int  # P — prompts are padded to exactly this length
    max_len: int     # T — KV-cache capacity / trajectory length
    batch: int       # B — rollout & train micro-batch baked into the HLO

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def max_new_tokens(self) -> int:
        return self.max_len - self.prompt_len

    def validate(self) -> None:
        assert self.d_model % self.n_heads == 0
        assert self.prompt_len % BLOCK_Q == 0, "prompt_len must tile"
        assert self.max_len % BLOCK_Q == 0, "max_len must tile"

    def param_count(self) -> int:
        per_layer = (
            2 * self.d_model                      # norms
            + 4 * self.d_model * self.d_model     # wq wk wv wo
            + 2 * self.d_model * self.d_ff        # w1 w2
        )
        return (
            2 * self.vocab * self.d_model         # embed + lm_head
            + self.d_model                        # final norm
            + self.n_layers * per_layer
        )


PRESETS: Dict[str, ModelConfig] = {
    # ~0.72M params — unit tests / quickstart; everything runs in seconds.
    "tiny": ModelConfig("tiny", vocab=256, d_model=128, n_heads=4,
                        n_layers=4, d_ff=384, prompt_len=32, max_len=96,
                        batch=8),
    # ~11M params — the end-to-end training example (examples/train_e2e.rs).
    "small": ModelConfig("small", vocab=256, d_model=384, n_heads=6,
                         n_layers=6, d_ff=1536, prompt_len=32, max_len=128,
                         batch=8),
    # ~124M params — GPT-2-small-class geometry; artifact generation works
    # but real CPU training is slow; used for analytic/planner work and
    # compile-only validation.
    "base": ModelConfig("base", vocab=4096, d_model=768, n_heads=12,
                        n_layers=12, d_ff=3072, prompt_len=64, max_len=192,
                        batch=4),
}


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def param_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab
    shapes: Dict[str, Tuple[int, ...]] = {
        "embed": (v, d),
        "final_norm": (d,),
        "lm_head": (d, v),
    }
    for i in range(cfg.n_layers):
        p = f"layer{i:02d}."
        shapes[p + "attn_norm"] = (d,)
        shapes[p + "wq"] = (d, d)
        shapes[p + "wk"] = (d, d)
        shapes[p + "wv"] = (d, d)
        shapes[p + "wo"] = (d, d)
        shapes[p + "mlp_norm"] = (d,)
        shapes[p + "w1"] = (d, ff)
        shapes[p + "w2"] = (ff, d)
    return shapes


def canonical_names(cfg: ModelConfig) -> List[str]:
    """The one true cross-language parameter ordering."""
    return sorted(param_shapes(cfg))


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, np.ndarray]:
    """Scaled-normal init (GPT-2 style: residual projections down-scaled)."""
    rng = np.random.default_rng(seed)
    out: Dict[str, np.ndarray] = {}
    resid_scale = 1.0 / math.sqrt(2 * cfg.n_layers)
    for name, shape in param_shapes(cfg).items():
        if name.endswith("norm"):
            out[name] = np.ones(shape, dtype=np.float32)
        else:
            std = 0.02
            if name.endswith(("wo", "w2")):
                std *= resid_scale
            out[name] = rng.normal(0.0, std, size=shape).astype(np.float32)
    return out


def params_to_tuple(params: Dict[str, jnp.ndarray], cfg: ModelConfig):
    return tuple(params[n] for n in canonical_names(cfg))


def tuple_to_params(tup, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    return dict(zip(canonical_names(cfg), tup))


# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------

def rmsnorm(x, w, eps=1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def _rope_angles(positions, d_head):
    """RoPE angle table: positions [...,], returns (cos, sin) [..., d_head/2]."""
    half = d_head // 2
    inv_freq = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions):
    """x: [..., T, d_head] (positions [T]) or [..., d_head] (scalar pos)."""
    d_head = x.shape[-1]
    cos, sin = _rope_angles(positions, d_head)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1)


def _split_heads(x, cfg):
    # [B, T, d_model] -> [B, H, T, d_head]
    b, t, _ = x.shape
    return x.reshape(b, t, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)


def _merge_heads(x, cfg):
    # [B, H, T, d_head] -> [B, T, d_model]
    b, h, t, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * d)


def forward_full(params: Dict[str, jnp.ndarray], ids: jnp.ndarray,
                 cfg: ModelConfig, collect_kv: bool = False):
    """Full-sequence causal forward.

    Args:
      ids: [B, T] int32 token ids.
      collect_kv: also return the per-layer K/V tensors, padded to
        cfg.max_len, stacked as [L, 2, B, H, max_len, d_head].
    Returns:
      logits [B, T, vocab] (and the KV stack when requested).
    """
    b, t = ids.shape
    x = params["embed"][ids]  # [B, T, d]
    positions = jnp.arange(t)
    kv_stack = []
    for i in range(cfg.n_layers):
        p = f"layer{i:02d}."
        h = rmsnorm(x, params[p + "attn_norm"])
        q = _split_heads(h @ params[p + "wq"], cfg)
        k = _split_heads(h @ params[p + "wk"], cfg)
        v = _split_heads(h @ params[p + "wv"], cfg)
        q = apply_rope(q, positions)
        k = apply_rope(k, positions)
        n = b * cfg.n_heads
        attn = flash_attention(
            q.reshape(n, t, cfg.d_head),
            k.reshape(n, t, cfg.d_head),
            v.reshape(n, t, cfg.d_head),
            BLOCK_Q, BLOCK_K,
        ).reshape(b, cfg.n_heads, t, cfg.d_head)
        x = x + _merge_heads(attn, cfg) @ params[p + "wo"]
        h = rmsnorm(x, params[p + "mlp_norm"])
        x = x + jax.nn.gelu(h @ params[p + "w1"]) @ params[p + "w2"]
        if collect_kv:
            pad = cfg.max_len - t
            k_pad = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
            v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
            kv_stack.append(jnp.stack([k_pad, v_pad], axis=0))
    x = rmsnorm(x, params["final_norm"])
    logits = x @ params["lm_head"]
    if collect_kv:
        return logits, jnp.stack(kv_stack, axis=0)
    return logits


# --------------------------------------------------------------------------
# AOT entry points
# --------------------------------------------------------------------------

def prefill(param_tup, prompt_ids, cfg: ModelConfig):
    """Prompt phase. prompt_ids [B, P] -> (last_logits [B, V], kv stack)."""
    params = tuple_to_params(param_tup, cfg)
    logits, kv = forward_full(params, prompt_ids, cfg, collect_kv=True)
    return logits[:, -1, :], kv


def decode_step(param_tup, kv, pos, token, cfg: ModelConfig):
    """One autoregressive step.

    Args:
      kv: [L, 2, B, H, max_len, d_head] cache; positions > pos-1 invalid.
      pos: [] int32 — the position the incoming token occupies.
      token: [B] int32 — tokens sampled at position pos (fed back in).
    Returns:
      (logits [B, V] for position pos, updated kv).
    """
    params = tuple_to_params(param_tup, cfg)
    return _decode_core(params, kv, pos, token, cfg)


def _decode_core(params, kv, pos, token, cfg: ModelConfig):
    b = token.shape[0]
    x = params["embed"][token][:, None, :]  # [B, 1, d]
    new_kv = []
    for i in range(cfg.n_layers):
        p = f"layer{i:02d}."
        h = rmsnorm(x, params[p + "attn_norm"])
        q = _split_heads(h @ params[p + "wq"], cfg)[:, :, 0, :]  # [B,H,dh]
        k = _split_heads(h @ params[p + "wk"], cfg)[:, :, 0, :]
        v = _split_heads(h @ params[p + "wv"], cfg)[:, :, 0, :]
        q = apply_rope(q, pos)
        k = apply_rope(k, pos)
        k_cache = jax.lax.dynamic_update_slice(
            kv[i, 0], k[:, :, None, :], (0, 0, pos, 0))
        v_cache = jax.lax.dynamic_update_slice(
            kv[i, 1], v[:, :, None, :], (0, 0, pos, 0))
        n = b * cfg.n_heads
        attn = decode_attention(
            q.reshape(n, cfg.d_head),
            k_cache.reshape(n, cfg.max_len, cfg.d_head),
            v_cache.reshape(n, cfg.max_len, cfg.d_head),
            pos, BLOCK_K,
        ).reshape(b, 1 * cfg.n_heads * cfg.d_head)
        x = x + (attn @ params[p + "wo"])[:, None, :]
        h = rmsnorm(x, params[p + "mlp_norm"])
        x = x + jax.nn.gelu(h @ params[p + "w1"]) @ params[p + "w2"]
        new_kv.append(jnp.stack([k_cache, v_cache], axis=0))
    x = rmsnorm(x, params["final_norm"])
    logits = (x @ params["lm_head"])[:, 0, :]
    return logits, jnp.stack(new_kv, axis=0)


# Token conventions shared with the Rust side (rust/src/data/mod.rs).
PAD_ID = 0
EOS_ID = 10  # '\n'


def _sample_token(logits, key, temperature, top_k):
    """Gumbel-max top-k sampling with a greedy fallback at temperature<=0.

    Args:
      logits: [B, V]; key: PRNG key; temperature: [] f32 (traced).
    Returns:
      (token [B] i32, logp [B] — log-prob of the chosen token under the
      FULL softmax, i.e. the behaviour-policy logprob GRPO needs).
    """
    # Top-k via threshold masking (NOT lax.top_k: jax lowers that to a
    # `TopK` HLO attribute form the bundled xla_extension 0.5.1 parser
    # rejects; Sort lowers cleanly).
    kth = jnp.sort(logits, axis=-1)[:, -top_k][:, None]
    masked = jnp.where(logits >= kth, logits, -1e30)
    g = jax.random.gumbel(key, logits.shape)
    greedy = jnp.argmax(logits, axis=-1)
    sampled = jnp.argmax(
        masked / jnp.maximum(temperature, 1e-6) + g, axis=-1)
    tok = jnp.where(temperature <= 0.0, greedy, sampled)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tok_logit = jnp.take_along_axis(logits, tok[:, None], axis=-1)[:, 0]
    return tok.astype(jnp.int32), tok_logit - logz


def rollout(param_tup, prompt_ids, seed, temperature, cfg: ModelConfig,
            top_k=32):
    """Fused on-device generation loop — the rollout hot path.

    Prefill + `lax.scan` over all decode positions with in-graph
    sampling, so the Rust engine issues ONE execution per generation
    batch instead of one per token (see EXPERIMENTS.md §Perf). Also emits
    the behaviour-policy ("old") logprobs for free — they are exactly the
    sampling-time logprobs.

    Args:
      prompt_ids: [B, P] int32 (fixed-width prompts).
      seed: [] int32 sampling seed; temperature: [] f32 (<=0 = greedy).
    Returns:
      (ids [B, T] int32 — prompt + response + PAD padding after EOS,
       old_logp [B, T-P] f32 — logp of each generated token; 0 after EOS).
    """
    params = tuple_to_params(param_tup, cfg)
    b, p = prompt_ids.shape
    logits, kv = forward_full(params, prompt_ids, cfg, collect_kv=True)
    last_logits = logits[:, -1, :]
    key0 = jax.random.PRNGKey(seed)

    def step(carry, pos):
        logits, kv, key, done = carry
        key, sub = jax.random.split(key)
        tok, logp = _sample_token(logits, sub, temperature, top_k)
        tok = jnp.where(done, PAD_ID, tok)
        logp = jnp.where(done, 0.0, logp)
        done = done | (tok == EOS_ID)
        logits, kv = _decode_core(params, kv, pos, tok, cfg)
        return (logits, kv, key, done), (tok, logp)

    init = (last_logits, kv, key0, jnp.zeros((b,), dtype=bool))
    _, (toks, logps) = jax.lax.scan(
        step, init, jnp.arange(p, cfg.max_len))
    ids = jnp.concatenate([prompt_ids, toks.T], axis=1)
    return ids, logps.T


def token_logprobs(param_tup, ids, cfg: ModelConfig):
    """Per-token log-probabilities. ids [B, T] -> logp [B, T-1].

    logp[b, t] = log P(ids[b, t+1] | ids[b, :t+1]).
    """
    params = tuple_to_params(param_tup, cfg)
    logits = forward_full(params, ids, cfg)  # [B, T, V]
    logz = jax.nn.logsumexp(logits[:, :-1, :], axis=-1)
    tgt = jnp.take_along_axis(
        logits[:, :-1, :], ids[:, 1:, None], axis=-1)[..., 0]
    return tgt - logz


def grpo_loss(param_tup, ids, adv, old_logp, ref_logp, mask,
              cfg: ModelConfig, clip_eps=0.2, kl_coef=0.05):
    """GRPO objective over one micro-batch of trajectories."""
    logp = token_logprobs(param_tup, ids, cfg)
    loss, policy_loss, kl = grpo_token_loss(
        logp, old_logp, ref_logp, adv, mask,
        clip_eps=clip_eps, kl_coef=kl_coef)
    # Masked mean entropy proxy: -logp of taken tokens over response region.
    denom = jnp.maximum(mask.sum(), 1.0)
    nll = -(logp * mask).sum() / denom
    return loss, (policy_loss, kl, nll)


def train_step(param_tup, m_tup, v_tup, step, ids, adv, old_logp, ref_logp,
               mask, lr, cfg: ModelConfig, clip_eps=0.2, kl_coef=0.05,
               beta1=0.9, beta2=0.95, eps=1e-8, grad_clip=1.0):
    """One Adam update on the GRPO objective.

    All state is threaded positionally (params / first moment / second
    moment in canonical order, then the scalar Adam step counter) so the
    Rust runtime can persist it between executions.

    Returns (params', m', v', step', loss, policy_loss, kl, nll, grad_norm).
    """
    (loss, (policy_loss, kl, nll)), grads = jax.value_and_grad(
        grpo_loss, has_aux=True)(
            param_tup, ids, adv, old_logp, ref_logp, mask, cfg,
            clip_eps=clip_eps, kl_coef=kl_coef)
    # Global-norm gradient clipping.
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads))
    scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-12))
    grads = tuple(g * scale for g in grads)

    step_new = step + 1.0
    bc1 = 1.0 - beta1 ** step_new
    bc2 = 1.0 - beta2 ** step_new
    new_p, new_m, new_v = [], [], []
    for p, m, v, g in zip(param_tup, m_tup, v_tup, grads):
        m = beta1 * m + (1.0 - beta1) * g
        v = beta2 * v + (1.0 - beta2) * g * g
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        new_p.append(p - lr * upd)
        new_m.append(m)
        new_v.append(v)
    return (tuple(new_p), tuple(new_m), tuple(new_v), step_new,
            loss, policy_loss, kl, nll, gnorm)
