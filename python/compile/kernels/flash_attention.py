"""Pallas flash-attention kernel (L1) — the model's compute hot-spot.

TPU-oriented structure (see DESIGN.md §Hardware-Adaptation): queries are
tiled into VMEM-sized blocks via BlockSpec; the kernel streams KV blocks
through an online-softmax accumulator (running max `m`, running normalizer
`l`, unnormalized accumulator `acc`), so the full [T, T] score matrix never
materializes. On a real TPU the per-block matmuls map onto the MXU systolic
array; here we lower with ``interpret=True`` so the kernel executes as plain
HLO on the CPU PJRT plugin (real-TPU lowering emits a Mosaic custom-call the
CPU client cannot run).

Backward pass: the kernel is wrapped in ``jax.custom_vjp``; the VJP
recomputes attention with the pure-jnp reference (flash-style
rematerialization — the standard trade of extra FLOPs for O(T) memory).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF, ref_causal_attention

# Block sizes: sized so q/k/v blocks + accumulators fit comfortably in a
# ~16 MiB VMEM budget at d_head <= 128 (see DESIGN.md §Perf for the
# footprint arithmetic).
DEFAULT_BLOCK_Q = 32
DEFAULT_BLOCK_K = 32


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, block_k, seq_len):
    """One grid step: one query block against all causal KV blocks.

    Refs (VMEM blocks):
      q_ref: [block_q, d]    — this grid step's query tile.
      k_ref: [seq_len, d]    — full K for this (batch*head).
      v_ref: [seq_len, d]    — full V for this (batch*head).
      o_ref: [block_q, d]    — output tile.
    """
    block_q, d = q_ref.shape
    qb = pl.program_id(1)  # query-block index
    q = q_ref[...] * scale
    q_pos = qb * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    num_kb = seq_len // block_k

    def body(kb, carry):
        acc, m_i, l_i = carry
        k_blk = pl.load(k_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        v_blk = pl.load(v_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        s = q @ k_blk.T  # [block_q, block_k]
        k_pos = kb * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1
        )
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_new = jnp.maximum(m_i, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + p.sum(axis=-1, keepdims=True)
        acc = acc * alpha + p @ v_blk
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, d), dtype=jnp.float32)
    m0 = jnp.full((block_q, 1), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q, 1), dtype=jnp.float32)
    # Causality: query block qb covers positions up to (qb+1)*block_q - 1,
    # so only kv blocks through ceil((qb+1)*block_q / block_k) can
    # contribute — this is the triangular-schedule FLOP saving real flash
    # attention gets (handles block_q != block_k).
    block_q_dim = q_ref.shape[0]
    upper = jnp.minimum(
        ((qb + 1) * block_q_dim + block_k - 1) // block_k, num_kb
    )
    acc, m_i, l_i = jax.lax.fori_loop(0, upper, body, (acc0, m0, l0))
    o_ref[...] = (acc / l_i).astype(o_ref.dtype)


def _flash_forward(q, k, v, *, block_q, block_k, interpret=True):
    n, t, d = q.shape
    assert t % block_q == 0 and t % block_k == 0, (t, block_q, block_k)
    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(
        _flash_kernel, scale=scale, block_k=block_k, seq_len=t
    )
    grid = (n, t // block_q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, t, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, t, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((n, t, d), q.dtype),
        interpret=interpret,
    )(q, k, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q, k, v, block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K):
    """Causal flash attention over [N, T, D] (N = batch*heads merged).

    Forward runs the Pallas kernel; backward rematerializes through the
    jnp reference (see module docstring).
    """
    return _flash_forward(q, k, v, block_q=block_q, block_k=block_k)


def _fa_fwd(q, k, v, block_q, block_k):
    out = _flash_forward(q, k, v, block_q=block_q, block_k=block_k)
    return out, (q, k, v)


def _fa_bwd(block_q, block_k, residuals, g):
    q, k, v = residuals
    _, vjp = jax.vjp(ref_causal_attention, q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)
