"""Pallas fused GRPO token-loss kernel (L1).

Fuses the per-token GRPO arithmetic — importance ratio, PPO-style clipping,
k3 KL estimator, masking — into a single elementwise kernel, so the lowered
HLO performs one pass over the [B, T] token grid instead of materializing
five intermediates (ratio, clipped, surrogate, log_r, kl). On TPU this is a
pure-VPU kernel (no MXU); its value is memory-bandwidth: 5 reads + 2 writes
per token instead of ~14 with unfused intermediates.

The kernel emits per-token (surrogate, kl) grids; the scalar reduction to
masked means stays in jnp (XLA fuses the reduce with the kernel output).
Backward: ``jax.custom_vjp`` — forward runs the Pallas kernel, backward
differentiates the pure-jnp elementwise form (rematerialization, same
pattern as the flash-attention kernel).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _grpo_kernel(logp_ref, old_ref, refp_ref, adv_ref, mask_ref,
                 surr_ref, kl_ref, *, clip_eps):
    logp = logp_ref[...]
    old = old_ref[...]
    refp = refp_ref[...]
    adv = adv_ref[...]  # [B, 1] broadcast over tokens
    mask = mask_ref[...]

    ratio = jnp.exp(logp - old)
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps)
    surr = jnp.minimum(ratio * adv, clipped * adv) * mask
    log_r = refp - logp
    kl = (jnp.exp(log_r) - log_r - 1.0) * mask
    surr_ref[...] = surr
    kl_ref[...] = kl


def _grpo_tokens_jnp(logp, old, refp, adv2d, mask, clip_eps):
    """Elementwise reference form — backward path + test oracle."""
    ratio = jnp.exp(logp - old)
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps)
    surr = jnp.minimum(ratio * adv2d, clipped * adv2d) * mask
    log_r = refp - logp
    kl = (jnp.exp(log_r) - log_r - 1.0) * mask
    return surr, kl


def _grpo_tokens_pallas(logp, old, refp, adv2d, mask, clip_eps, interpret):
    b, t = logp.shape
    kernel = functools.partial(_grpo_kernel, clip_eps=clip_eps)
    full = pl.BlockSpec((b, t), lambda: (0, 0))
    return pl.pallas_call(
        kernel,
        grid=(),
        in_specs=[full, full, full,
                  pl.BlockSpec((b, 1), lambda: (0, 0)),
                  full],
        out_specs=[full, full],
        out_shape=[
            jax.ShapeDtypeStruct((b, t), logp.dtype),
            jax.ShapeDtypeStruct((b, t), logp.dtype),
        ],
        interpret=interpret,
    )(logp, old, refp, adv2d, mask)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _grpo_tokens(logp, old, refp, adv2d, mask, clip_eps, interpret):
    return _grpo_tokens_pallas(logp, old, refp, adv2d, mask, clip_eps,
                               interpret)


def _gt_fwd(logp, old, refp, adv2d, mask, clip_eps, interpret):
    out = _grpo_tokens_pallas(logp, old, refp, adv2d, mask, clip_eps,
                              interpret)
    return out, (logp, old, refp, adv2d, mask)


def _gt_bwd(clip_eps, interpret, residuals, g):
    logp, old, refp, adv2d, mask = residuals
    _, vjp = jax.vjp(
        lambda *a: _grpo_tokens_jnp(*a, clip_eps), logp, old, refp, adv2d,
        mask)
    return vjp(g)


_grpo_tokens.defvjp(_gt_fwd, _gt_bwd)


def grpo_token_loss(logp, old_logp, ref_logp, adv, mask,
                    clip_eps=0.2, kl_coef=0.05, interpret=True):
    """Fused GRPO loss. Shapes: logp/old/ref/mask [B, T]; adv [B].

    Returns (loss, policy_loss, kl_mean) scalars — identical semantics to
    ``ref.ref_grpo_token_loss``.
    """
    surr, kl = _grpo_tokens(logp, old_logp, ref_logp, adv[:, None], mask,
                            clip_eps, interpret)
    denom = jnp.maximum(mask.sum(), 1.0)
    policy_loss = -surr.sum() / denom
    kl_mean = kl.sum() / denom
    return policy_loss + kl_coef * kl_mean, policy_loss, kl_mean
