"""Pallas single-query (decode-step) attention kernel (L1).

The autoregressive rollout hot-spot: one query token per sequence attends
to a KV cache of fixed capacity T, with positions > ``pos`` masked out.
This is the TPU analogue of a paged/decode attention kernel — the KV cache
streams through VMEM in blocks while a single query row sits resident; the
online-softmax carry makes the pass single-sweep.

``pos`` arrives as a [1] int32 array placed in scalar-friendly memory so
the mask is computed inside the kernel (no host-side remasking per step).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF

DEFAULT_BLOCK_K = 32


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, scale, block_k,
                   seq_len):
    """One grid step: one (batch*head)'s query row against its KV cache.

    Refs:
      pos_ref: [1] int32 — current position (keys 0..pos valid).
      q_ref:   [1, d]    — the query row.
      k_ref:   [seq_len, d]
      v_ref:   [seq_len, d]
      o_ref:   [1, d]
    """
    d = q_ref.shape[-1]
    pos = pos_ref[0]
    q = q_ref[...] * scale  # [1, d]
    num_kb = seq_len // block_k

    def body(kb, carry):
        acc, m_i, l_i = carry
        k_blk = pl.load(k_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        v_blk = pl.load(v_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        s = q @ k_blk.T  # [1, block_k]
        k_pos = kb * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1
        )
        s = jnp.where(k_pos <= pos, s, NEG_INF)
        m_new = jnp.maximum(m_i, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + p.sum(axis=-1, keepdims=True)
        acc = acc * alpha + p @ v_blk
        return acc, m_new, l_new

    acc0 = jnp.zeros((1, d), dtype=jnp.float32)
    m0 = jnp.full((1, 1), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((1, 1), dtype=jnp.float32)
    # Only blocks covering positions <= pos contribute.
    upper = jnp.minimum(pos // block_k + 1, num_kb)
    acc, m_i, l_i = jax.lax.fori_loop(0, upper, body, (acc0, m0, l0))
    o_ref[...] = (acc / l_i).astype(o_ref.dtype)


def decode_attention(q, k, v, pos, block_k=DEFAULT_BLOCK_K, interpret=True):
    """Decode-step attention.

    Args:
      q: [N, D] current-position queries (N = batch*heads merged).
      k, v: [N, T, D] KV caches.
      pos: [] or [1] int32 — the current position.
    Returns:
      [N, D]
    """
    n, t, d = k.shape
    assert t % block_k == 0, (t, block_k)
    scale = 1.0 / (d ** 0.5)
    pos_arr = jnp.asarray(pos, dtype=jnp.int32).reshape((1,))
    kernel = functools.partial(
        _decode_kernel, scale=scale, block_k=block_k, seq_len=t
    )
    out = pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((None, 1, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, t, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, t, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, 1, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1, d), q.dtype),
        interpret=interpret,
    )(pos_arr, q[:, None, :], k, v)
    return out[:, 0, :]
