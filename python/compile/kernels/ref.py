"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has an exact (up to float tolerance)
counterpart here. pytest + hypothesis sweep shapes/dtypes and assert
``allclose(kernel(...), ref(...))`` — this is the core L1 correctness signal.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def ref_causal_attention(q, k, v, scale=None):
    """Causal self-attention, full sequence.

    Args:
      q, k, v: [N, T, D] (N = batch * heads, already merged).
      scale: optional softmax scale; defaults to 1/sqrt(D).
    Returns:
      [N, T, D] attention output.
    """
    n, t, d = q.shape
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.array(d, dtype=q.dtype))
    logits = jnp.einsum("ntd,nsd->nts", q, k) * scale
    causal = jnp.tril(jnp.ones((t, t), dtype=bool))
    logits = jnp.where(causal[None, :, :], logits, NEG_INF)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("nts,nsd->ntd", probs, v)


def ref_decode_attention(q, k, v, pos, scale=None):
    """Single-query attention against a KV cache of max length T.

    Args:
      q: [N, D] query for the current position.
      k, v: [N, T, D] KV cache (positions > pos are garbage and must be
        masked out).
      pos: scalar int32 — the current position; keys 0..pos inclusive are
        valid.
    Returns:
      [N, D] attention output.
    """
    n, t, d = k.shape
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.array(d, dtype=q.dtype))
    logits = jnp.einsum("nd,ntd->nt", q, k) * scale
    valid = jnp.arange(t) <= pos
    logits = jnp.where(valid[None, :], logits, NEG_INF)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("nt,ntd->nd", probs, v)


def ref_grpo_token_loss(logp, old_logp, ref_logp, adv, mask,
                        clip_eps=0.2, kl_coef=0.05):
    """Per-token GRPO loss (clipped surrogate + k3 KL penalty).

    Args:
      logp, old_logp, ref_logp: [B, T] per-token log-probabilities under the
        current policy, the behaviour (rollout-time) policy, and the frozen
        reference policy.
      adv: [B] group-relative advantage, broadcast over response tokens.
      mask: [B, T] 1.0 on response tokens, 0.0 on prompt/padding.
    Returns:
      (loss_scalar, policy_loss_scalar, kl_scalar) — all masked means.
    """
    ratio = jnp.exp(logp - old_logp)
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps)
    adv_b = adv[:, None]
    surrogate = jnp.minimum(ratio * adv_b, clipped * adv_b)
    # k3 KL estimator: exp(ref - logp) - (ref - logp) - 1  (>= 0)
    log_r = ref_logp - logp
    kl = jnp.exp(log_r) - log_r - 1.0
    denom = jnp.maximum(mask.sum(), 1.0)
    policy_loss = -(surrogate * mask).sum() / denom
    kl_mean = (kl * mask).sum() / denom
    return policy_loss + kl_coef * kl_mean, policy_loss, kl_mean
