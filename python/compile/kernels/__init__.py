"""L1 Pallas kernels + pure-jnp reference oracles."""

from .decode_attention import decode_attention
from .flash_attention import flash_attention
from .grpo_loss import grpo_token_loss
from . import ref

__all__ = ["decode_attention", "flash_attention", "grpo_token_loss", "ref"]
