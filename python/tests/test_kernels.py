"""L1 correctness: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps shapes/positions/seeds; every case asserts allclose
against ``kernels.ref``. This is the core kernel-correctness signal —
the AOT artifacts embed exactly these kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (decode_attention, flash_attention,
                             grpo_token_loss)
from compile.kernels.grpo_loss import _grpo_tokens_jnp
from compile.kernels.ref import (ref_causal_attention, ref_decode_attention,
                                 ref_grpo_token_loss)

SETTINGS = dict(max_examples=12, deadline=None)


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    n=st.integers(1, 6),
    t_blocks=st.integers(1, 6),
    d=st.sampled_from([8, 16, 32, 64]),
    block=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_matches_ref(n, t_blocks, d, block, seed):
    t = t_blocks * block
    rng = np.random.default_rng(seed)
    q, k, v = (_rand(rng, n, t, d) for _ in range(3))
    out = flash_attention(q, k, v, block, block)
    ref = ref_causal_attention(q, k, v)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_flash_attention_mixed_blocks():
    rng = np.random.default_rng(0)
    q, k, v = (_rand(rng, 2, 64, 16) for _ in range(3))
    ref = ref_causal_attention(q, k, v)
    for bq, bk in [(16, 32), (32, 16), (64, 16), (16, 64)]:
        out = flash_attention(q, k, v, bq, bk)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_flash_attention_grad_matches_ref():
    rng = np.random.default_rng(7)
    q, k, v = (_rand(rng, 3, 32, 16) for _ in range(3))
    g = jax.grad(lambda *a: flash_attention(*a).sum(), argnums=(0, 1, 2))(
        q, k, v)
    gr = jax.grad(lambda *a: ref_causal_attention(*a).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_flash_attention_under_jit():
    rng = np.random.default_rng(3)
    q, k, v = (_rand(rng, 2, 48, 16) for _ in range(3))
    out = jax.jit(lambda q, k, v: flash_attention(q, k, v, 16, 16))(q, k, v)
    np.testing.assert_allclose(out, ref_causal_attention(q, k, v),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_rejects_untileable():
    rng = np.random.default_rng(0)
    q, k, v = (_rand(rng, 1, 33, 8) for _ in range(3))
    with pytest.raises(AssertionError):
        flash_attention(q, k, v, 16, 16)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    n=st.integers(1, 6),
    t_blocks=st.integers(1, 4),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
    pos_frac=st.floats(0.0, 1.0),
)
def test_decode_attention_matches_ref(n, t_blocks, d, seed, pos_frac):
    t = t_blocks * 32
    pos = min(int(pos_frac * t), t - 1)
    rng = np.random.default_rng(seed)
    q = _rand(rng, n, d)
    k, v = (_rand(rng, n, t, d) for _ in range(2))
    out = decode_attention(q, k, v, pos)
    ref = ref_decode_attention(q, k, v, pos)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_decode_attention_ignores_garbage_tail():
    """Cache positions beyond pos must not leak into the output."""
    rng = np.random.default_rng(1)
    q = _rand(rng, 2, 16)
    k, v = (_rand(rng, 2, 64, 16) for _ in range(2))
    pos = 10
    out1 = decode_attention(q, k, v, pos)
    k2 = k.at[:, pos + 1:, :].set(1e6)  # poison the tail
    v2 = v.at[:, pos + 1:, :].set(-1e6)
    out2 = decode_attention(q, k2, v2, pos)
    np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)


def test_decode_matches_flash_last_row():
    """Decode at pos=T-1 equals the last row of full causal attention."""
    rng = np.random.default_rng(5)
    n, t, d = 4, 32, 16
    q_full, k, v = (_rand(rng, n, t, d) for _ in range(3))
    full = ref_causal_attention(q_full, k, v)
    out = decode_attention(q_full[:, -1, :], k, v, t - 1)
    np.testing.assert_allclose(out, full[:, -1, :], rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# grpo_token_loss
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    b=st.integers(1, 8),
    t=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
    clip_eps=st.sampled_from([0.1, 0.2, 0.3]),
    kl_coef=st.sampled_from([0.0, 0.05, 0.5]),
)
def test_grpo_loss_matches_ref(b, t, seed, clip_eps, kl_coef):
    rng = np.random.default_rng(seed)
    logp, old, refp = (0.2 * _rand(rng, b, t) - 1.0 for _ in range(3))
    adv = _rand(rng, b)
    mask = jnp.asarray((rng.random((b, t)) > 0.3).astype(np.float32))
    got = grpo_token_loss(logp, old, refp, adv, mask, clip_eps, kl_coef)
    want = ref_grpo_token_loss(logp, old, refp, adv, mask, clip_eps, kl_coef)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=2e-5, atol=2e-6)


def test_grpo_loss_grad_matches_ref():
    rng = np.random.default_rng(11)
    b, t = 4, 24
    logp, old, refp = (0.2 * _rand(rng, b, t) - 1.0 for _ in range(3))
    adv = _rand(rng, b)
    mask = jnp.ones((b, t), dtype=jnp.float32)
    g = jax.grad(lambda lp: grpo_token_loss(lp, old, refp, adv, mask)[0])(
        logp)
    gr = jax.grad(lambda lp: ref_grpo_token_loss(lp, old, refp, adv,
                                                 mask)[0])(logp)
    np.testing.assert_allclose(g, gr, rtol=2e-5, atol=2e-6)


def test_grpo_loss_zero_mask_is_finite():
    b, t = 2, 8
    z = jnp.zeros((b, t), dtype=jnp.float32)
    loss, pl_, kl = grpo_token_loss(z, z, z, jnp.zeros((b,)), z)
    assert np.isfinite(float(loss)) and float(pl_) == 0.0 and float(kl) == 0.0


def test_grpo_kl_nonnegative():
    rng = np.random.default_rng(13)
    b, t = 4, 16
    logp, refp = (0.5 * _rand(rng, b, t) - 1.0 for _ in range(2))
    mask = jnp.ones((b, t), dtype=jnp.float32)
    _, kl = _grpo_tokens_jnp(logp, logp, refp, jnp.ones((b, 1)), mask, 0.2)
    assert float(kl.min()) >= 0.0


def test_grpo_onpolicy_loss_equals_negative_advantage():
    """With logp == old_logp == ref_logp, loss = -mean(adv broadcast)."""
    rng = np.random.default_rng(17)
    b, t = 4, 16
    logp = 0.2 * _rand(rng, b, t)
    adv = _rand(rng, b)
    mask = jnp.ones((b, t), dtype=jnp.float32)
    loss, pl_, kl = grpo_token_loss(logp, logp, logp, adv, mask, 0.2, 0.05)
    assert abs(float(kl)) < 1e-7
    np.testing.assert_allclose(float(pl_), -float(adv.mean()), rtol=1e-5)
