"""L2 correctness: model entry points, decode/prefill consistency, GRPO
training dynamics, parameter bookkeeping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.PRESETS["tiny"]


@pytest.fixture(scope="module")
def setup():
    params = {k: jnp.asarray(v) for k, v in M.init_params(CFG).items()}
    tup = M.params_to_tuple(params, CFG)
    rng = np.random.default_rng(42)
    ids = jnp.asarray(rng.integers(
        0, CFG.vocab, size=(CFG.batch, CFG.max_len), dtype=np.int32))
    return params, tup, ids, rng


def test_param_count_matches_analytic(setup):
    params, _, _, _ = setup
    total = sum(int(np.prod(v.shape)) for v in params.values())
    assert total == CFG.param_count()


def test_canonical_names_sorted_and_complete(setup):
    params, _, _, _ = setup
    names = M.canonical_names(CFG)
    assert names == sorted(names)
    assert set(names) == set(params)


def test_presets_validate():
    for cfg in M.PRESETS.values():
        cfg.validate()


def test_prefill_shapes(setup):
    _, tup, ids, _ = setup
    logits, kv = M.prefill(tup, ids[:, :CFG.prompt_len], CFG)
    assert logits.shape == (CFG.batch, CFG.vocab)
    assert kv.shape == (CFG.n_layers, 2, CFG.batch, CFG.n_heads,
                        CFG.max_len, CFG.d_head)


def test_prefill_matches_full_forward(setup):
    params, tup, ids, _ = setup
    prompt = ids[:, :CFG.prompt_len]
    last, _ = M.prefill(tup, prompt, CFG)
    full = M.forward_full(params, prompt, CFG)
    np.testing.assert_allclose(last, full[:, -1, :], rtol=1e-5, atol=1e-5)


def test_decode_chain_matches_full_forward(setup):
    """Prefill + N decode steps must reproduce teacher-forced logits."""
    params, tup, ids, _ = setup
    upto = CFG.prompt_len + 16
    sub = ids[:, :upto]
    full = M.forward_full(params, sub, CFG)
    _, kv = M.prefill(tup, ids[:, :CFG.prompt_len], CFG)
    for t in range(CFG.prompt_len, upto):
        step_logits, kv = M.decode_step(tup, kv, jnp.int32(t), ids[:, t],
                                        CFG)
        np.testing.assert_allclose(step_logits, full[:, t, :],
                                   rtol=5e-4, atol=5e-4)


def test_logprobs_shape_and_range(setup):
    _, tup, ids, _ = setup
    lp = M.token_logprobs(tup, ids, CFG)
    assert lp.shape == (CFG.batch, CFG.max_len - 1)
    assert float(lp.max()) <= 1e-5  # log-probabilities are <= 0
    assert np.isfinite(np.asarray(lp)).all()


def test_logprobs_sum_to_one(setup):
    """exp(logprobs) over the vocab axis must be a distribution."""
    params, _, ids, _ = setup
    logits = M.forward_full(params, ids[:, :CFG.prompt_len], CFG)
    probs = jax.nn.softmax(logits, axis=-1)
    np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, rtol=1e-5)


def test_train_step_moves_params_and_reduces_loss(setup):
    """A few steps on a fixed batch with positive advantage must increase
    the trajectory log-likelihood (the GRPO surrogate pushes it up)."""
    _, tup, ids, rng = setup
    m = tuple(jnp.zeros_like(p) for p in tup)
    v = tuple(jnp.zeros_like(p) for p in tup)
    step = jnp.float32(0.0)
    adv = jnp.ones((CFG.batch,), dtype=jnp.float32)
    mask = jnp.ones((CFG.batch, CFG.max_len - 1), dtype=jnp.float32)
    old = M.token_logprobs(tup, ids, CFG)
    ref = old
    lp0 = float((old * mask).sum() / mask.sum())
    cur = tup
    for _ in range(3):
        out = M.train_step(cur, m, v, step, ids, adv, old, ref, mask,
                           jnp.float32(3e-4), CFG)
        cur, m, v, step = out[0], out[1], out[2], out[3]
        assert np.isfinite(float(out[4]))
    lp1 = float((M.token_logprobs(cur, ids, CFG) * mask).sum() / mask.sum())
    assert lp1 > lp0, (lp0, lp1)
    assert float(step) == 3.0


def test_train_step_zero_lr_is_identity(setup):
    _, tup, ids, _ = setup
    m = tuple(jnp.zeros_like(p) for p in tup)
    v = tuple(jnp.zeros_like(p) for p in tup)
    adv = jnp.ones((CFG.batch,), dtype=jnp.float32)
    mask = jnp.ones((CFG.batch, CFG.max_len - 1), dtype=jnp.float32)
    old = M.token_logprobs(tup, ids, CFG)
    out = M.train_step(tup, m, v, jnp.float32(0.0), ids, adv, old, old,
                       mask, jnp.float32(0.0), CFG)
    for a, b in zip(out[0], tup):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_rope_preserves_norm():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 4, 8, 16)).astype(np.float32))
    y = M.apply_rope(x, jnp.arange(8))
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)


def test_rope_relative_shift_invariance():
    """RoPE dot products depend only on relative position."""
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(1, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 16)).astype(np.float32))
    def dot(pq, pk):
        qr = M.apply_rope(q, jnp.int32(pq))
        kr = M.apply_rope(k, jnp.int32(pk))
        return float((qr * kr).sum())
    np.testing.assert_allclose(dot(5, 3), dot(9, 7), rtol=1e-4)
    np.testing.assert_allclose(dot(10, 0), dot(15, 5), rtol=1e-4)
