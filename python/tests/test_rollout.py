"""Fused on-device rollout (model.rollout): sampling semantics, EOS
handling, and behaviour-logprob consistency with token_logprobs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

CFG = M.PRESETS["tiny"]


@pytest.fixture(scope="module")
def setup():
    params = {k: jnp.asarray(v) for k, v in M.init_params(CFG).items()}
    tup = M.params_to_tuple(params, CFG)
    rng = np.random.default_rng(5)
    prompt = jnp.asarray(rng.integers(
        32, 120, size=(CFG.batch, CFG.prompt_len), dtype=np.int32))
    return tup, prompt


def test_rollout_shapes_and_prompt_preserved(setup):
    tup, prompt = setup
    ids, logp = M.rollout(tup, prompt, jnp.int32(7), jnp.float32(1.0), CFG)
    assert ids.shape == (CFG.batch, CFG.max_len)
    assert logp.shape == (CFG.batch, CFG.max_new_tokens)
    np.testing.assert_array_equal(
        np.asarray(ids[:, :CFG.prompt_len]), np.asarray(prompt))


def test_rollout_greedy_ignores_seed(setup):
    tup, prompt = setup
    a, _ = M.rollout(tup, prompt, jnp.int32(1), jnp.float32(0.0), CFG)
    b, _ = M.rollout(tup, prompt, jnp.int32(999), jnp.float32(0.0), CFG)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_rollout_seed_changes_samples(setup):
    tup, prompt = setup
    a, _ = M.rollout(tup, prompt, jnp.int32(1), jnp.float32(1.0), CFG)
    b, _ = M.rollout(tup, prompt, jnp.int32(2), jnp.float32(1.0), CFG)
    assert not np.array_equal(np.asarray(a), np.asarray(b))


def test_rollout_pad_after_eos(setup):
    tup, prompt = setup
    ids, logp = M.rollout(tup, prompt, jnp.int32(3), jnp.float32(1.2), CFG)
    ids = np.asarray(ids)
    logp = np.asarray(logp)
    p = CFG.prompt_len
    for r in range(CFG.batch):
        resp = ids[r, p:]
        eos_pos = np.where(resp == M.EOS_ID)[0]
        if eos_pos.size:
            after = resp[eos_pos[0] + 1:]
            assert (after == M.PAD_ID).all(), f"row {r}: junk after EOS"
            assert (logp[r, eos_pos[0] + 1:] == 0.0).all()


def test_rollout_logp_matches_token_logprobs(setup):
    """Sampling-time logps must equal the scoring path's logps — this is
    the contract that lets the Rust engine skip the extra behaviour-policy
    forward (EXPERIMENTS.md §Perf)."""
    tup, prompt = setup
    ids, logp = M.rollout(tup, prompt, jnp.int32(11), jnp.float32(1.0), CFG)
    full = np.asarray(M.token_logprobs(tup, ids, CFG))
    roll = np.asarray(logp)
    ids = np.asarray(ids)
    p = CFG.prompt_len
    for r in range(CFG.batch):
        for j in range(CFG.max_new_tokens):
            tok = ids[r, p + j]
            if tok == M.PAD_ID:
                break
            np.testing.assert_allclose(
                full[r, p - 1 + j], roll[r, j], rtol=1e-3, atol=1e-4)
            if tok == M.EOS_ID:
                break


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       temp=st.sampled_from([0.5, 1.0, 2.0]))
def test_sample_token_stays_in_topk(seed, temp):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32) * 3)
    key = jax.random.PRNGKey(seed)
    top_k = 8
    tok, logp = M._sample_token(logits, key, jnp.float32(temp), top_k)
    sorted_logits = np.sort(np.asarray(logits), axis=-1)
    kth = sorted_logits[:, -top_k]
    chosen = np.take_along_axis(
        np.asarray(logits), np.asarray(tok)[:, None], axis=-1)[:, 0]
    assert (chosen >= kth - 1e-6).all(), "sampled outside top-k"
    # logp really is the full-softmax logprob
    ref = chosen - np.log(np.exp(np.asarray(logits)).sum(axis=-1))
    np.testing.assert_allclose(np.asarray(logp), ref, rtol=1e-4, atol=1e-5)


def test_sample_token_greedy_is_argmax():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
    key = jax.random.PRNGKey(0)
    tok, _ = M._sample_token(logits, key, jnp.float32(0.0), 8)
    np.testing.assert_array_equal(
        np.asarray(tok), np.asarray(jnp.argmax(logits, axis=-1)))
