"""AOT pipeline: manifest/arg-spec integrity + params.bin round-trip.

The lowering itself (``lower_all``) is exercised once on the tiny preset —
it is the exact code path ``make artifacts`` runs.
"""

import json
import os

import numpy as np
import pytest

from compile import aot, model as M, params_io

CFG = M.PRESETS["tiny"]


@pytest.fixture(scope="module")
def lowered():
    return aot.lower_all(CFG)


def test_all_artifacts_present(lowered):
    assert set(lowered) == {"prefill", "rollout", "decode_step", "logprobs",
                            "train_step"}


def test_hlo_text_is_parseable_hlo(lowered):
    for name, (hlo, _, _) in lowered.items():
        assert hlo.startswith("HloModule"), name
        assert "ENTRY" in hlo, name


def test_arg_counts(lowered):
    n = len(M.canonical_names(CFG))
    assert len(lowered["prefill"][1]) == n + 1
    assert len(lowered["rollout"][1]) == n + 3
    assert len(lowered["decode_step"][1]) == n + 3
    assert len(lowered["logprobs"][1]) == n + 1
    assert len(lowered["train_step"][1]) == 3 * n + 1 + 6
    assert len(lowered["train_step"][2]) == 3 * n + 1 + len(aot.METRIC_NAMES)


def test_hlo_entry_arity_matches_manifest(lowered):
    """The HLO ENTRY signature must declare exactly the manifest's args —
    this is the contract the Rust runtime relies on positionally."""
    for name, (hlo, args, _) in lowered.items():
        # Parameters of the ENTRY computation appear as `parameter(i)`
        # instructions after the ENTRY line (ENTRY is the last computation
        # in jax-emitted HLO text).
        entry_at = hlo.index("\nENTRY ")
        n_params = hlo[entry_at:].count(" parameter(")
        assert n_params == len(args), (name, n_params, len(args))


def test_params_bin_roundtrip(tmp_path):
    params = M.init_params(CFG, seed=3)
    path = os.path.join(tmp_path, "p.bin")
    params_io.write_params(path, params)
    back = params_io.read_params(path)
    assert set(back) == set(params)
    for k in params:
        np.testing.assert_array_equal(params[k], back[k])


def test_init_params_deterministic():
    a = M.init_params(CFG, seed=0)
    b = M.init_params(CFG, seed=0)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    c = M.init_params(CFG, seed=1)
    assert any(not np.array_equal(a[k], c[k]) for k in a)


def test_build_writes_manifest(tmp_path):
    out = str(tmp_path / "art")
    aot.build("tiny", out)
    with open(os.path.join(out, "manifest.json")) as f:
        man = json.load(f)
    assert man["preset"] == "tiny"
    assert man["model"]["param_count"] == CFG.param_count()
    assert man["param_names"] == M.canonical_names(CFG)
    for art in ["prefill", "decode_step", "logprobs", "train_step"]:
        meta = man["artifacts"][art]
        assert os.path.exists(os.path.join(out, meta["file"]))
        assert len(meta["args"]) > 0 and len(meta["results"]) > 0
    assert os.path.exists(os.path.join(out, "params.bin"))
